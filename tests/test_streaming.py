"""End-to-end token streaming: the step-wise generator APIs (Engine,
Scheduler) and the proxy's incremental token channel.

The governing invariant: streamed output is BIT-EXACT with the buffered
path — same greedy decode, same token cap, same text — across dense, paged
and speculative decoding; a cancelled stream tears its slot down, releases
its pages, and settles only the tokens actually generated."""
import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import (Constraints, ModelPool, PoolModel, Preference,
                        ProxyRequest, build_bridge, pool_model_from_config)
from repro.core.api import TokenStream
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_model
from repro.serving.engine import DraftEngine, Engine
from repro.serving.scheduler import Request, Scheduler

MAX_LEN = 64


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_reduced("qwen2-1.5b")
    return Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)),
                  max_len=MAX_LEN)


@pytest.fixture(scope="module")
def small_engine(engine):
    cfg = dataclasses.replace(engine.cfg, n_layers=1)
    return Engine(cfg, init_model(cfg, jax.random.PRNGKey(7)),
                  max_len=MAX_LEN + DraftEngine.HEADROOM)


def _prompts(seed=0, lens=(9, 17, 33, 5)):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(3, 90, n).tolist(), jnp.int32)
            for n in lens]


# -- TokenStream (the channel itself) -----------------------------------------

class TestTokenStream:
    def test_emit_iterate_close(self):
        s = TokenStream()
        assert s.emit("he", token_ids=(1,))
        assert s.emit("llo", token_ids=(2, 3))
        s.close()
        chunks = list(s)
        assert [c.text for c in chunks[:-1]] == ["he", "llo"]
        assert chunks[-1].final
        assert s.text == "hello"

    def test_cancel_stops_producer(self):
        s = TokenStream(maxsize=1)
        assert s.emit("a")
        s.cancel()
        assert not s.emit("b")          # producer sees the drop
        s.close()                       # terminal marker still lands
        assert s.cancelled

    def test_timing_stats(self):
        s = TokenStream()
        s.emit("a"), s.emit("b"), s.emit("c")
        s.close()
        list(s)
        assert s.ttft() is not None and s.ttft() >= 0.0
        assert s.inter_token_p50() is not None

    def test_error_surfaces_to_consumer(self):
        s = TokenStream()
        s.emit("partial")
        s.close(error=RuntimeError("boom"))
        with pytest.raises(RuntimeError, match="boom"):
            list(s)


# -- Engine.generate_stream ----------------------------------------------------

class TestEngineStream:
    def test_stream_matches_generate(self, engine):
        prompt = jnp.asarray([_prompts(seed=3, lens=(12,))[0].tolist()],
                             jnp.int32).reshape(1, -1)
        base = np.asarray(engine.generate(prompt, max_new=10))
        cols = list(engine.generate_stream(prompt, max_new=10))
        got = np.stack(cols, axis=1)
        np.testing.assert_array_equal(got, base)

    def test_stream_matches_generate_with_eos(self, engine):
        prompt = jnp.asarray([_prompts(seed=4, lens=(8,))[0].tolist()],
                             jnp.int32).reshape(1, -1)
        base = np.asarray(engine.generate(prompt, max_new=12))
        eos = int(base[0, len(base[0]) // 2])     # an emitted token as EOS
        trimmed = np.asarray(engine.generate(prompt, max_new=12, eos_id=eos))
        cols = list(engine.generate_stream(prompt, max_new=12, eos_id=eos))
        got = np.stack(cols, axis=1)
        # identical columns up to the streamed length, and the stream stops
        # at (or before, same poll cadence) the buffered trim point
        np.testing.assert_array_equal(got, trimmed[:, :got.shape[1]])
        assert got.shape[1] <= 12


# -- Scheduler.step_stream / run_stream ---------------------------------------

def _stream_collect(engine, prompts, max_new=12, **sched_kw):
    sch = Scheduler(engine, n_slots=len(prompts), **sched_kw)
    for i, p in enumerate(prompts):
        sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=max_new))
    got = {}
    for req, new_toks, done in sch.run_stream():
        got.setdefault(req.rid, []).extend(new_toks)
    return sch, got


def _buffered(engine, prompts, max_new=12, **sched_kw):
    sch = Scheduler(engine, n_slots=len(prompts), **sched_kw)
    for i, p in enumerate(prompts):
        sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=max_new))
    return {r.rid: list(r.generated) for r in sch.run_to_completion()}


class TestSchedulerStream:
    def test_dense_stream_bit_exact(self, engine):
        base = _buffered(engine, _prompts())
        _, got = _stream_collect(engine, _prompts())
        assert got == base

    def test_paged_stream_bit_exact(self, engine):
        base = _buffered(engine, _prompts(seed=1), paged=True, page_size=4)
        sch, got = _stream_collect(engine, _prompts(seed=1), paged=True,
                                   page_size=4)
        assert got == base
        sch.pool.check()

    def test_spec_stream_bit_exact_bursts(self, engine, small_engine):
        base = _buffered(engine, _prompts(seed=2), paged=True, page_size=4)
        draft = DraftEngine(small_engine, n_slots=4, max_len=MAX_LEN)
        sch = Scheduler(engine, n_slots=4, paged=True, page_size=4,
                        draft=draft, spec_k=4)
        for i, p in enumerate(_prompts(seed=2)):
            sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=12))
        got, burst_sizes = {}, []
        for req, new_toks, done in sch.run_stream():
            got.setdefault(req.rid, []).extend(new_toks)
            burst_sizes.append(len(new_toks))
        assert sch.spec_stats["enabled"]
        assert got == base
        # spec rounds emit accepted prefixes as bursts: at least one event
        # must carry more than one token (acceptance > 0 somewhere)
        assert max(burst_sizes) > 1
        sch.pool.check()

    def test_cancel_releases_slot_and_pages(self, engine):
        sch = Scheduler(engine, n_slots=2, paged=True, page_size=4)
        for i, p in enumerate(_prompts(seed=5, lens=(9, 17))):
            sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=24))
        # decode a few steps, then cancel rid 0 mid-flight
        for _ in range(3):
            sch.step_stream()
        assert any(r is not None and r.rid == 0 for r in sch.slots)
        assert sch.cancel(0)
        assert all(r is None or r.rid != 0 for r in sch.slots)
        assert sch.user_inflight["u0"] is False
        # the survivor decodes to completion; refcounts stay consistent
        # (trie-resident prefix pages remain, LRU-evictable, by design)
        for _ in sch.run_stream():
            pass
        sch.pool.check()

    def test_cancel_queued_request(self, engine):
        sch = Scheduler(engine, n_slots=1)
        for i, p in enumerate(_prompts(seed=6, lens=(9, 11))):
            sch.submit(Request(rid=i, user="same-user", prompt=p, max_new=8))
        # rid 1 is queued behind rid 0 (per-user FIFO)
        assert sch.cancel(1)
        done = sch.run_to_completion()
        assert [r.rid for r in done] == [0]


# -- proxy: request_stream ------------------------------------------------------

def _sim_req(user, prompt="stream me a story", **cons):
    return ProxyRequest(prompt=prompt, user=user,
                        constraints=Constraints(allow_cache=False, **cons),
                        preference=Preference.COST_FIRST)


class TestProxyStream:
    def test_sim_stream_bit_exact_with_buffered(self):
        bridge = build_bridge()
        buffered = bridge.request(_sim_req("u-buf"))
        chunks = list(bridge.request_stream(_sim_req("u-str")))
        final = chunks[-1]
        assert final.final and final.response is not None
        text = "".join(c.text for c in chunks)
        assert text == final.response.text == buffered.text

    def test_stream_metadata_and_stats(self):
        bridge = build_bridge()
        chunks = list(bridge.request_stream(_sim_req("u1")))
        md = chunks[-1].response.metadata
        assert md.stream is True
        assert md.ttft is not None and md.ttft >= 0.0
        assert md.inter_token_p50 is not None
        serving = bridge.stats()["serving"]
        assert serving["streams"] == 1
        assert len(serving["ttft_cdf"]) == 1
        assert serving["ttft_p50_s"] == serving["ttft_cdf"][0]

    def test_stream_cost_matches_buffered(self):
        a, b = build_bridge(), build_bridge()
        buffered = a.request(_sim_req("u"))
        chunks = list(b.request_stream(_sim_req("u")))
        assert (chunks[-1].response.metadata.usage.cost
                == pytest.approx(buffered.metadata.usage.cost))
        assert a.ledger.spent("u") == pytest.approx(b.ledger.spent("u"))

    def test_cache_hit_streams_one_final_chunk(self):
        bridge = build_bridge()
        bridge.cache.put_exact("cache warm probe", "the cached answer")
        hit = ProxyRequest(prompt="cache warm probe", user="w",
                           constraints=Constraints(),
                           preference=Preference.COST_FIRST)
        chunks = list(bridge.request_stream(hit))
        resp = chunks[-1].response
        assert resp.metadata.cache_hit
        # one content chunk (the fallback full-text emit) + the final marker
        assert len(chunks) == 2
        assert chunks[0].text == resp.text

    def test_cancellation_settles_partial_cost(self):
        full = build_bridge()
        complete = full.request(_sim_req("u"))
        full_cost = complete.metadata.usage.cost

        bridge = build_bridge()
        gen = bridge.request_stream(_sim_req("u"), buffer=1)
        next(gen), next(gen)            # take two chunks, then hang up
        gen.close()
        spent = bridge.ledger.spent("u")
        assert 0.0 < spent < full_cost
        assert bridge.stats()["serving"]["streams_cancelled"] == 1

    def test_legacy_service_type_streams_with_warning(self):
        from repro.core import ServiceType
        bridge = build_bridge()
        with pytest.warns(DeprecationWarning):
            chunks = list(bridge.request_stream(ProxyRequest(
                prompt="legacy stream", user="u",
                service_type=ServiceType.COST)))
        assert chunks[-1].response is not None


# -- proxy: engine-backed (REAL) streaming -------------------------------------

def _real_bridge(engine, draft_engine=None):
    tok = ByteTokenizer()
    base = pool_model_from_config(configs.get("qwen2-1.5b"))
    pool = ModelPool()
    pool.add(PoolModel(name=base.name, active_params=base.active_params,
                       capability=base.capability, engine=engine,
                       tokenizer=tok, draft_engine=draft_engine))
    return build_bridge(pool=pool)


def _real_req(user, max_tokens=12):
    return ProxyRequest(prompt="abcd", user=user,
                        constraints=Constraints(allow_cache=False),
                        preference=Preference.COST_FIRST,
                        params={"max_tokens": max_tokens})


class TestRealEngineStream:
    def test_real_stream_bit_exact(self, engine):
        bridge = _real_bridge(engine)
        buffered = bridge.request(_real_req("u-buf"))
        chunks = list(bridge.request_stream(_real_req("u-str")))
        text = "".join(c.text for c in chunks)
        assert text == chunks[-1].response.text == buffered.text
        assert buffered.metadata.usage.cost == pytest.approx(
            chunks[-1].response.metadata.usage.cost)

    def test_real_spec_stream_bit_exact(self, engine, small_engine):
        plain = _real_bridge(engine)
        buffered = plain.request(_real_req("u-buf"))
        spec = _real_bridge(engine, draft_engine=small_engine)
        chunks = list(spec.request_stream(_real_req("u-str")))
        text = "".join(c.text for c in chunks)
        assert text == buffered.text
        assert chunks[-1].response.metadata.spec_acceptance is not None

    def test_real_cancellation_frees_and_partially_charges(self, engine):
        bridge = _real_bridge(engine)
        full_cost = bridge.request(
            _real_req("u-full")).metadata.usage.cost
        gen = bridge.request_stream(_real_req("u", max_tokens=12), buffer=1)
        next(gen)                       # first token only, then hang up
        gen.close()
        spent = bridge.ledger.spent("u")
        assert 0.0 < spent < full_cost


# -- admission: submit_stream --------------------------------------------------

class TestAdmissionStream:
    def test_submit_stream_chunks_match_result(self):
        bridge = build_bridge()
        t = bridge.submit_stream(_sim_req("u1"))
        got = []
        consumer = threading.Thread(
            target=lambda: got.extend(t.chunks()))
        consumer.start()
        bridge.admission.drain()
        resp = t.result(timeout=10)
        consumer.join(timeout=10)
        assert not consumer.is_alive()
        assert "".join(c.text for c in got) == resp.text
        assert resp.metadata.queue_wait is not None
        assert resp.metadata.stream is True
        assert bridge.admission.stats()["streamed"] == 1

    def test_streaming_batch_does_not_block_formation(self):
        """With a streaming ticket in flight, the next pump() can still
        form and dispatch a batch — decode happens on the worker."""
        bridge = build_bridge()
        t1 = bridge.submit_stream(_sim_req("u1"))
        got = []
        consumer = threading.Thread(target=lambda: got.extend(t1.chunks()))
        consumer.start()
        bridge.admission.dispatch()     # returns before decode completes
        t2 = bridge.submit(ProxyRequest(
            prompt="buffered rider", user="u2",
            constraints=Constraints(), preference=Preference.COST_FIRST))
        bridge.admission.drain()
        assert t2.result().text
        assert t1.result(timeout=10).text
        consumer.join(timeout=10)
        assert "".join(c.text for c in got) == t1.result().text

    def test_ticket_chunks_requires_streaming(self):
        bridge = build_bridge()
        t = bridge.submit(ProxyRequest(
            prompt="plain", user="u", constraints=Constraints(),
            preference=Preference.COST_FIRST))
        with pytest.raises(RuntimeError, match="submit_stream"):
            t.chunks()
        bridge.admission.drain()
        assert t.result().text
