"""Property tests for the PolicyCompiler's budget guarantees.

Hypothesis-based (skipped at collection by the conftest guard when
hypothesis is absent): compiled pipelines must never exceed
``Constraints.max_cost`` and a ledger-constrained user can never be
overdrawn, for arbitrary constraint draws over the planted workload.
"""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (CachedType, Constraints, Preference, ProxyRequest,
                        Workload, WorkloadConfig, build_bridge)


@pytest.fixture(scope="module")
def workload():
    return Workload(WorkloadConfig(n_conversations=4, turns_per_conversation=8,
                                   seed=11))


def _bridge_with_cache(workload):
    bridge = build_bridge(workload=workload, seed=0)
    for q in workload.queries[::2]:
        bridge.cache.put(q.text + " background facts. " * 5,
                         [(CachedType.CHUNK, q.text)], meta={"topic": q.topic})
    return bridge


# max_cost floor comfortably above the semantic cache's small-model consult
# bound on this workload, so cache-only degradation also stays inside it
@settings(max_examples=20, deadline=None)
@given(max_cost=st.floats(0.005, 2.0),
       preference=st.sampled_from(list(Preference)),
       allow_cache=st.booleans())
def test_compiled_pipelines_never_exceed_max_cost(workload, max_cost,
                                                  preference, allow_cache):
    bridge = _bridge_with_cache(workload)
    cons = Constraints(max_cost=max_cost, allow_cache=allow_cache)
    for q in workload.queries[:4]:
        r = bridge.request(ProxyRequest(
            prompt=q.text, conversation=q.conversation, query=q,
            preference=preference, constraints=cons))
        bridge.flush_prefetch()   # prefetch spend settles into usage.cost
        assert r.metadata.usage.cost <= max_cost + 1e-9
        assert r.metadata.policy.startswith(f"intent:{preference.value}")


@settings(max_examples=15, deadline=None)
@given(budget=st.floats(0.01, 5.0),
       preference=st.sampled_from([Preference.COST_FIRST, Preference.BALANCED,
                                   Preference.QUALITY_FIRST]))
def test_ledger_is_never_overdrawn(workload, budget, preference):
    bridge = build_bridge(workload=workload, seed=0)
    bridge.ledger.set_budget("u", budget)
    tiers, last = [], None
    for q in workload.queries[:8]:
        last = bridge.request(ProxyRequest(
            prompt=q.text, conversation=q.conversation, query=q, user="u",
            update_context=False, preference=preference,
            constraints=Constraints(allow_cache=False)))
        tiers.append(last.metadata.budget_tier)
    bridge.regenerate(last)   # escalation is budget-fitted too
    assert bridge.ledger.spent("u") <= budget + 1e-9
    assert bridge.ledger.remaining("u") >= -1e-9
    assert tiers == sorted(tiers)
