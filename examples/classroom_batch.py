"""Classroom deployment (paper §5.2): usage-based service types.

    PYTHONPATH=src python examples/classroom_batch.py

* pool restricted to a curated cheap-model subset (the paper's GPT4o-mini /
  Phi-3 / Haiku / LLaMA-3 analogue) via pool filters;
* per-student token quotas enforced at the proxy;
* RAG-style workflow: course documents delegated-PUT into the semantic
  cache (chunking + typed keys by the cache-LLM), then answered via
  smart_cache;
* a batch-mode sweep comparing models on the same prompts (§5.2's
  "benchmarking" usage pattern).
"""

from repro.core import (ProxyRequest, ServiceType, Workload,
                        WorkloadConfig, build_bridge)

wl = Workload(WorkloadConfig(n_conversations=3, turns_per_conversation=8, seed=42))
bridge = build_bridge(workload=wl)

# --- curated cheap pool (course policy) -------------------------------------
allowed = [m.name for m in bridge.pool.filter(max_price_in=0.05)]
print("course-approved models:", allowed)

# --- upload course material (delegated PUT: cache-LLM chunks + keys) --------
syllabus = (
    "Week 1 covers distributed systems basics. Consistency models matter.\n\n"
    "Week 2 covers consensus. Paxos and Raft are the core algorithms; "
    "leader election and log replication are the key mechanisms.\n\n"
    "Week 3 covers MapReduce and dataflow engines. Stragglers are mitigated "
    "with speculative execution."
)
ids = bridge.cache.delegated_put(syllabus, meta={"doc": "syllabus"})
types = {e.key_type.value for e in bridge.cache._entries}
print(f"syllabus -> {len(ids)} cache entries, key types: {sorted(types)}")

# --- per-student quotas -------------------------------------------------------
QUOTA = 5_000
spent = {f"student{i}": 0 for i in range(3)}
for i, q in enumerate(wl.queries[:12]):
    user = f"student{i % 3}"
    if spent[user] > QUOTA:
        print(f"[{user}] quota exhausted — request rejected")
        continue
    r = bridge.request(ProxyRequest(
        prompt=q.text, user=user, conversation=user, query=q,
        service_type=ServiceType.FIXED,
        params={"model": allowed[0], "context_k": 1}))
    u = r.metadata.usage
    spent[user] += u.input_tokens + u.output_tokens
print("token spend:", spent)

# --- RAG query through smart_cache -------------------------------------------
r = bridge.request(ProxyRequest(prompt="what is raft", user="student0",
                                conversation="student0",
                                service_type=ServiceType.SMART_CACHE))
print(f"RAG answer (cache_hit={r.metadata.cache_hit}, "
      f"types={r.metadata.cache_types}): {r.text[:64]}")

# --- batch-mode model comparison (the future interface §5.2 motivates) ------
prompt_q = wl.queries[0]
print("\nbatch-mode sweep:")
for name in allowed[:3] + ["gemma3-27b"]:
    r = bridge.request(ProxyRequest(
        prompt=prompt_q.text, user="student1", conversation="bench",
        query=prompt_q, update_context=False,
        service_type=ServiceType.FIXED, params={"model": name, "context_k": 0}))
    print(f"  {name:26s} cost={r.metadata.usage.cost:.4f} "
          f"quality={r.true_quality and round(r.true_quality, 1)}")
