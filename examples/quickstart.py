"""Quickstart: the LLMBridge public API in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the default bridge (model pool over the assigned architectures,
semantic cache, context manager, judge), sends a few prompts under different
service types, inspects the transparency metadata, and regenerates.
"""
from repro.core import ProxyRequest, ServiceType, Workload, WorkloadConfig, build_bridge

# a small planted workload (stands in for live WhatsApp traffic — DESIGN.md §2)
workload = Workload(WorkloadConfig(n_conversations=1, turns_per_conversation=6))
bridge = build_bridge(workload=workload)

q0, q1 = workload.queries[0], workload.queries[1]

# 1) delegate everything: verification-based model selection (paper §3.3)
resp = bridge.request(ProxyRequest(
    prompt=q0.text, user="alice", conversation="demo",
    service_type=ServiceType.MODEL_SELECTOR, query=q0))
md = resp.metadata
print(f"Q: {q0.text}")
print(f"A: {resp.text[:70]}")
print(f"   model={md.model_used} consulted={md.models_consulted}")
print(f"   verifier_score={md.verifier_score} context_k={md.context_k}")
print(f"   cost={md.usage.cost:.4f} latency~{md.usage.latency:.2f}s")

# 2) not satisfied? iterate — same service type escalates quality (§3.2)
better = bridge.regenerate(resp)
print(f"regenerated with {better.metadata.model_used} "
      f"(cost={better.metadata.usage.cost:.4f})")

# 3) smart context: a low-cost model decides whether history is needed (§3.4)
resp2 = bridge.request(ProxyRequest(
    prompt=q1.text, user="alice", conversation="demo",
    service_type=ServiceType.SMART_CONTEXT, query=q1))
print(f"smart_context kept k={resp2.metadata.context_k} messages "
      f"({resp2.metadata.context_strategy})")

# 4) populate the semantic cache and answer from it (§3.5)
bridge.cache.put("Use data structures like B-trees & Tries",
                 [("prompt", "How do I speed up my cache?"),
                  ("response", "Use data structures like B-trees & Tries")])
hits = bridge.cache.get("Give me examples of popular data structures?",
                        filters=[("response", 0.0, 2)])
print(f"cache GET by response-key similarity: {len(hits)} hit(s), "
      f"top score={hits[0].score:.2f}" if hits else "cache miss")
