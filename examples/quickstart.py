"""Quickstart: the LLMBridge public API in ~50 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the default bridge (model pool over the assigned architectures,
semantic cache, context manager, judge), states *intents* (Constraints +
Preference — the compiler picks the mechanisms), streams a response
token-by-token, inspects the transparency metadata, and regenerates.
"""
from repro.core import (Constraints, Preference, ProxyRequest, Workload,
                        WorkloadConfig, build_bridge)

# a small planted workload (stands in for live WhatsApp traffic — DESIGN.md §2)
workload = Workload(WorkloadConfig(n_conversations=1, turns_per_conversation=6))
bridge = build_bridge(workload=workload)

q0, q1 = workload.queries[0], workload.queries[1]

# 1) state an intent: quality floor + cost ceiling; the policy compiler
#    picks the mechanisms (verification, context, caching) to honor it
resp = bridge.request(ProxyRequest(
    prompt=q0.text, user="alice", conversation="demo",
    constraints=Constraints(min_quality=6.0, max_cost=0.05),
    preference=Preference.BALANCED, query=q0))
md = resp.metadata
print(f"Q: {q0.text}")
print(f"A: {resp.text[:70]}")
print(f"   policy={md.policy} model={md.model_used} "
      f"consulted={md.models_consulted}")
print(f"   verifier_score={md.verifier_score} context_k={md.context_k}")
print(f"   cost={md.usage.cost:.4f} latency~{md.usage.latency:.2f}s")

# 2) not satisfied? iterate — the escalation ladder raises quality (§3.2)
better = bridge.regenerate(resp)
print(f"regenerated with {better.metadata.model_used} "
      f"(cost={better.metadata.usage.cost:.4f})")

# 3) stream a response: chunks arrive as tokens land; the final chunk
#    carries the full ProxyResponse with TTFT disclosed in the metadata
chunks = []
for chunk in bridge.request_stream(ProxyRequest(
        prompt=q1.text, user="alice", conversation="demo",
        constraints=Constraints(allow_cache=False),
        preference=Preference.COST_FIRST, query=q1)):
    chunks.append(chunk)
streamed = chunks[-1].response
print(f"streamed {len(chunks) - 1} chunks, "
      f"ttft={streamed.metadata.ttft * 1e3:.2f}ms, "
      f"text == buffered shape: {''.join(c.text for c in chunks) == streamed.text}")

# 4) populate the semantic cache and answer from it (§3.5)
bridge.cache.put("Use data structures like B-trees & Tries",
                 [("prompt", "How do I speed up my cache?"),
                  ("response", "Use data structures like B-trees & Tries")])
hits = bridge.cache.get("Give me examples of popular data structures?",
                        filters=[("response", 0.0, 2)])
print(f"cache GET by response-key similarity: {len(hits)} hit(s), "
      f"top score={hits[0].score:.2f}" if hits else "cache miss")
