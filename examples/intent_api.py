"""API v2 walkthrough: delegate an intent, inspect Metadata v2, iterate.

    PYTHONPATH=src python examples/intent_api.py

The bidirectional loop the paper argues for (§3.2), on the v2 request plane:

1. *delegate*  — state Constraints + a Preference instead of picking a
   service type; the PolicyCompiler picks the mechanisms;
2. *inspect*   — Metadata v2 discloses the compiled policy, the budget
   tier, and per-stage StageRecords (wall-time, decision, cost delta);
3. *iterate*   — tighten the constraints (or regenerate) and resubmit;
4. *govern*    — give a user a BudgetLedger budget and watch compiled
   plans degrade monotonically instead of overdrawing;
5. *observe*   — proxy.stats() aggregates per-stage wall-time and
   hit/decision rates across every request served (Fig 6-style, live).
"""
from repro.core import (Constraints, Preference, ProxyRequest, Workload,
                        WorkloadConfig, build_bridge)


def show(tag, resp):
    md = resp.metadata
    print(f"\n[{tag}] policy={md.policy}  model={md.model_used}  "
          f"cost={md.usage.cost:.4f}  tier={md.budget_tier}")
    for rec in md.stage_records:
        print(f"    {rec.name:16s} {rec.duration * 1e6:8.1f}us  "
              f"decision={rec.decision:24s} cost+={rec.cost_delta:.4f}")


def main() -> None:
    wl = Workload(WorkloadConfig(n_conversations=4, turns_per_conversation=6))
    bridge = build_bridge(workload=wl, seed=0)
    q = wl.queries[0]

    # 1. delegate: quality-first, but never spend more than 2 cost units
    req = ProxyRequest(prompt=q.text, conversation=q.conversation, query=q,
                       preference=Preference.QUALITY_FIRST,
                       constraints=Constraints(max_cost=2.0))
    r1 = bridge.request(req)
    show("quality-first, max_cost=2.0", r1)

    # 2-3. inspect, then iterate with a tightened cost ceiling: the compiler
    # degrades the plan (cheaper route / tighter context) instead of refusing
    for cap in (0.5, 0.05, 0.002):
        r = bridge.request(ProxyRequest(
            prompt=q.text, conversation=q.conversation, query=q,
            preference=Preference.QUALITY_FIRST,
            constraints=Constraints(max_cost=cap)))
        show(f"tightened to max_cost={cap}", r)
        assert r.metadata.usage.cost <= cap + 1e-9

    # latency-first: instant cheap answer, background prefetch; regenerate
    # serves the prefetched high-quality answer with zero wait
    r = bridge.request(ProxyRequest(
        prompt=q.text, conversation=q.conversation, query=q,
        preference=Preference.LATENCY_FIRST, constraints=Constraints()))
    show("latency-first (prefetching in background)", r)
    better = bridge.regenerate(r)
    show("regenerate -> served from prefetch cache", better)

    # 4. govern: a per-user budget; plans degrade monotonically, never overdraw
    bridge.ledger.set_budget("metered-user", 3.0)
    print("\nbudget-governed run (budget=3.0):")
    for query in wl.queries[:12]:
        resp = bridge.request(ProxyRequest(
            prompt=query.text, conversation=query.conversation, query=query,
            user="metered-user", update_context=False,
            preference=Preference.QUALITY_FIRST,
            constraints=Constraints(allow_cache=False)))
        md = resp.metadata
        print(f"    tier={md.budget_tier}  model={md.model_used:22s} "
              f"cost={md.usage.cost:.4f}  remaining={md.budget_remaining:.4f}")
    assert bridge.ledger.spent("metered-user") <= 3.0

    # 5. observe: proxy-wide per-stage telemetry
    stats = bridge.stats()
    print("\nproxy.stats() — request path:")
    for name, s in stats["paths"]["request"]["stages"].items():
        print(f"    {name:16s} n={s['count']:3d}  p50={s['p50_s'] * 1e6:8.1f}us "
              f" p95={s['p95_s'] * 1e6:8.1f}us  decisions={s['decisions']}")
    print(f"cache: {stats['cache']}")
    print(f"ledger: {stats['ledger']}")


if __name__ == "__main__":
    main()
