"""Provider fleet under chaos: breaker, fallback, hedging, recovery.

    PYTHONPATH=src python examples/provider_fleet.py

The bridge fronts many LLM backends; real providers fail.  This walkthrough
injects faults into the SIM pool and watches the reliability layer respond:

* a 25% error rate everywhere — bounded retry-against-healthy keeps the
  answer rate up, and every response discloses its provider trail;
* a hard outage on the routed (cheapest) provider mid-run — its circuit
  breaker opens, traffic shifts to the next-healthiest backend, then
  half-open probes close the breaker once the outage ends;
* latency-first hedging — when the primary stalls past its tracked p95, a
  hedge fires at the next-healthiest provider and the winner is kept (the
  loser's spend is disclosed, never charged to the user's ledger).

Everything (failures, latency, the clock) is modelled and seeded, so the
run replays exactly.
"""

from repro.core import (CircuitBreaker, Constraints, FaultSpec, Preference,
                        ProxyRequest, ServiceType, Workload, WorkloadConfig,
                        build_bridge)

wl = Workload(WorkloadConfig(n_conversations=4, turns_per_conversation=8,
                             seed=11))


def req(bridge_i, **kw):
    q = wl.queries[bridge_i % len(wl.queries)]
    return ProxyRequest(prompt=q.text, user="demo", conversation="demo",
                        service_type=ServiceType.COST, query=q,
                        update_context=False, **kw)


# --- 1. flaky everywhere: retry-against-healthy -----------------------------
bridge = build_bridge(workload=wl, seed=0)
for m in bridge.pool.list():
    bridge.providers.configure(m.name, FaultSpec(error_rate=0.25))

served = 0
for i in range(30):
    r = bridge.request(req(i))
    served += r.metadata.model_used != "error"
    if r.metadata.provider_attempts > 1:
        print(f"  req {i:2d}: {r.metadata.provider_attempts} attempts "
              f"-> {r.metadata.provider}  events={r.metadata.provider_events}")
snap = bridge.stats()["providers"]
print(f"flaky fleet: {served}/30 served, {snap['retries']} retries, "
      f"{snap['exhausted']} exhausted\n")

# --- 2. hard outage on the routed provider: breaker opens, then recovers ----
bridge = build_bridge(workload=wl, seed=0)
target = bridge.pool.cheapest().name
bridge.providers.configure(
    target, FaultSpec(outages=((4.0, 18.0),)),
    breaker=CircuitBreaker(failure_threshold=3, cooldown=5.0))
print(f"outage window 4s-18s on {target!r} (the routed cheapest model)")

last_state = "closed"
for i in range(50):
    now = bridge.providers.now()
    r = bridge.request(req(i))
    state = bridge.stats()["providers"]["providers"][target]["state"]
    if state != last_state:
        print(f"  t={now:5.1f}s  breaker {last_state} -> {state}  "
              f"(answered by {r.metadata.provider})")
        last_state = state
trail = bridge.stats()["providers"]["providers"][target]
print(f"final state={trail['state']}, transitions:")
for t, frm, to in trail["transitions"]:
    print(f"  t={t:5.1f}s  {frm} -> {to}")
print()

# --- 3. latency-first hedging against a stall tail --------------------------
def stall_trace(hedge):
    """Same seed, same requests: 12% of attempts hang to a 10s timeout."""
    bridge = build_bridge(workload=wl, seed=0)
    for m in bridge.pool.list():
        bridge.providers.configure(
            m.name, FaultSpec(timeout_rate=0.12, timeout_s=10.0,
                              latency_sigma=0.15))
    bridge.providers.hedge_enabled = hedge
    bridge.providers.max_attempts = 4
    lats = []
    for i in range(150):
        r = bridge.request(req(
            i, constraints=Constraints(allow_cache=False,
                                       allow_prefetch=False),
            preference=Preference.LATENCY_FIRST))
        lats.append(r.metadata.usage.latency)
        if hedge and "hedge:fired" in r.metadata.provider_events:
            won = "hedge:won" in r.metadata.provider_events
            print(f"  req {i:2d}: hedge fired -> "
                  f"{'hedge won' if won else 'primary won'} "
                  f"({r.metadata.provider}, {r.metadata.usage.latency:.2f}s, "
                  f"wasted ${r.metadata.hedge_wasted_cost:.6f})")
    lats.sort()
    return bridge, lats[int(0.95 * len(lats))]


_, p95_off = stall_trace(hedge=False)
bridge, p95_on = stall_trace(hedge=True)
h = bridge.stats()["providers"]["hedges"]
print(f"hedging: {h['fired']} fired / {h['won']} won, "
      f"p95 latency {p95_off:.2f}s without -> {p95_on:.2f}s with, "
      f"wasted ${h['wasted_cost']:.6f} disclosed — "
      f"ledger spent ${bridge.ledger.spent('demo'):.6f}")
