"""Fair classroom: one heavy user + N light users sharing a proxy.

    PYTHONPATH=src python examples/fair_classroom.py

The paper's deployment (§4) routes every user through a per-user FIFO so a
heavy user cannot starve the class.  This example drives the admission
front-end the same way:

* a "crammer" fires 4 questions per round, four classmates one each;
* ``bridge.submit`` enqueues into per-user FIFOs (intent holds land at
  enqueue), ``pump()`` forms cross-user batches — rotating round-robin,
  one request per user per batch — and dispatches them through the
  batched embed/search/decode hot path;
* the crammer also has a nearly-empty budget: under contention they yield
  their turn to funded classmates, but the bounded-wait rule means they
  are deferred, never starved.
"""

from repro.core import (AdmissionController, ProxyRequest, ServiceType,
                        Workload, WorkloadConfig, build_bridge)

wl = Workload(WorkloadConfig(n_conversations=5, turns_per_conversation=10,
                             seed=21))
bridge = build_bridge(workload=wl)
bridge.attach_admission(AdmissionController(bridge, max_batch=4, max_wait=0.0,
                                            yield_tier=2, max_yields=3))

students = ["crammer"] + [f"student{i}" for i in range(4)]
# the crammer has nearly exhausted their course budget -> depleted tier
bridge.ledger.set_budget("crammer", 1.0)
bridge.ledger.charge("crammer", 0.92)

qi = 0
order = []
for rnd in range(6):
    for user in students:
        n = 4 if user == "crammer" else 1          # 4:1 arrival skew
        for _ in range(n):
            q = wl.queries[qi % len(wl.queries)]
            qi += 1
            bridge.submit(ProxyRequest(
                prompt=q.text, user=user, conversation=user, query=q,
                service_type=ServiceType.COST, update_context=False))
    for t in bridge.admission.pump():
        order.append(t.req.user)

# while the class contends for slots, service is even-handed
contended = bridge.stats()["admission"]
print("contended-phase completions:", contended["completed_per_user"])
print(f"contended-phase Jain index:  {contended['jain_index']:.3f}")

# end of the lab session: drain the backlog (the crammer's surplus runs
# after everyone else has been served — deferred, not dropped)
for t in bridge.admission.drain():
    order.append(t.req.user)

stats = bridge.stats()["admission"]
print("final completions per user:", stats["completed_per_user"])
print("batch-size histogram:", stats["batch_size_hist"])
print(f"queue wait p50/p99:   {stats['queue_wait_p50_s'] * 1e3:.2f}ms / "
      f"{stats['queue_wait_p99_s'] * 1e3:.2f}ms")
print(f"crammer budget yields: {stats['budget_yields']} "
      f"(tier {bridge.ledger.tier('crammer')}; deferred, never starved)")
print("first 12 completions:", order[:12])
