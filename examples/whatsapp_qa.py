"""End-to-end driver: the WhatsApp Q&A service (paper §5.1) over LLMBridge,
serving a real (reduced-config) model with batched requests.

    PYTHONPATH=src python examples/whatsapp_qa.py [--users 6] [--turns 4]

What it exercises (all real code paths):
* a pool with REAL engines (reduced configs, random weights) behind the
  proxy — actual prefill/decode with KV caches via the continuous-batching
  scheduler with per-user FIFO (the paper's SQS analogue);
* perplexity judging (a real verifier forward pass) for model selection;
* follow-up prefetching into the exact-match cache + "button press" hits;
* "Get Better Answer" = proxy.regenerate.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import (Constraints, ModelPool, PoolModel, Preference,
                        ProxyRequest, Workload, WorkloadConfig, build_bridge,
                        pool_model_from_config)
from repro.core.judge import Judge
from repro.data.tokenizer import ByteTokenizer
from repro.models import init_model
from repro.serving.engine import Engine
from repro.serving.scheduler import Request, Scheduler


def build_real_pool(archs=("qwen2-1.5b", "gemma-2b")):
    tok = ByteTokenizer()
    pool = ModelPool()
    engines = {}
    for i, arch in enumerate(archs):
        cfg = configs.get_reduced(arch)
        params = init_model(cfg, jax.random.PRNGKey(i))
        eng = Engine(cfg, params, max_len=160)
        base = pool_model_from_config(configs.get(arch))
        pool.add(PoolModel(name=base.name, active_params=base.active_params,
                           capability=base.capability, engine=eng, tokenizer=tok))
        engines[arch] = (cfg, params, eng)
    return pool, engines, tok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=4)
    ap.add_argument("--turns", type=int, default=3)
    args = ap.parse_args()

    wl = Workload(WorkloadConfig(n_conversations=args.users,
                                 turns_per_conversation=args.turns))
    pool, engines, tok = build_real_pool()
    bridge = build_bridge(workload=wl, pool=pool)
    vcfg, vparams, _ = engines["qwen2-1.5b"]
    bridge.judge = Judge(mode="perplexity", verifier_cfg=vcfg,
                        verifier_params=vparams, tokenizer=tok)

    t0 = time.time()
    n, cache_hits = 0, 0
    for conv, qs in wl.conversations().items():
        user = conv.replace("conv", "user")
        for q in qs:
            # balanced intent: the compiler's first ladder rung is
            # verification-based model selection (the old MODEL_SELECTOR)
            r = bridge.request(ProxyRequest(
                prompt=q.text, user=user, conversation=conv,
                constraints=Constraints(), preference=Preference.BALANCED))
            n += 1
            cache_hits += r.metadata.cache_hit
            # prefetch 2 follow-ups into the exact-match cache (buttons)
            for i in range(2):
                f = f"{q.text} — tell me more ({i})"
                bridge.cache.put_exact(f, f"[prefetched] {r.text[:40]}…")
            print(f"[{user}] {q.text[:44]:44s} -> {r.metadata.model_used:12s} "
                  f"score={r.metadata.verifier_score}")
        # the user presses a follow-up button: served from cache, no LLM call
        # (cost-first intents consult the cache before spending on a model)
        b = bridge.request(ProxyRequest(
            prompt=f"{qs[-1].text} — tell me more (0)", user=user,
            conversation=conv, constraints=Constraints(),
            preference=Preference.COST_FIRST))
        assert b.metadata.cache_hit and b.metadata.cache_types == ["exact"]
        cache_hits += 1
        n += 1

    # "Get Better Answer" on the last response
    last_q = qs[-1]
    r = bridge.request(ProxyRequest(prompt=last_q.text, user=user,
                                    conversation=conv,
                                    constraints=Constraints(),
                                    preference=Preference.BALANCED))
    better = bridge.regenerate(r)
    print(f"\n'Get Better Answer': {r.metadata.model_used} -> "
          f"{better.metadata.model_used}")

    # batched low-level serving through the scheduler (the substrate the
    # pool engines run on)
    cfg, params, eng = engines["gemma-2b"]
    sched = Scheduler(eng, n_slots=4)
    for i in range(6):
        ids = tok.encode(f"batched question {i}")[:24]
        sched.submit(Request(rid=i, user=f"user{i % 3}",
                             prompt=jnp.asarray(ids, jnp.int32), max_new=8))
    done = sched.run_to_completion()
    print(f"scheduler: {len(done)} batched requests decoded "
          f"({sum(len(r.generated) for r in done)} tokens)")
    print(f"total: {n} proxy requests, {cache_hits} cache hits, "
          f"{time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
