"""Train a ~100M-param model for a few hundred steps (deliverable (b)).

    PYTHONPATH=src python examples/train_small.py [--steps 300]

Uses the full training substrate: synthetic corpus with planted bigram
structure, pure-JAX AdamW with warmup+cosine, checkpointing.  The config is
the qwen2-1.5b family shrunk to ~100M params (not the 2-layer smoke config).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.models import init_model
from repro.models.params import count_params
from repro.training import checkpoint
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_100m.msgpack")
    args = ap.parse_args()

    # ~100M params: 12 layers, d=512, vocab 32k
    cfg = dataclasses.replace(
        configs.get_reduced("qwen2-1.5b", dtype="float32"),
        name="qwen2-100m", n_layers=12, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=2048, vocab=32_000)
    params = init_model(cfg, jax.random.PRNGKey(0))
    print(f"params: {count_params(params)/1e6:.1f}M")

    oc = OptConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps)
    step = jax.jit(make_train_step(cfg, oc))
    opt = init_opt_state(params)
    it = SyntheticCorpus(cfg.vocab, DataConfig(batch=8, seq_len=128)).batches(cfg)

    t0, first = time.time(), None
    for i in range(args.steps):
        b = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt, m = step(params, opt, b)
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={loss:.4f} lr={float(m['lr']):.2e} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print(f"loss {first:.3f} -> {loss:.3f} over {args.steps} steps")
    checkpoint.save(args.ckpt, params, {"cfg": cfg.name, "steps": args.steps})
    print(f"checkpoint: {args.ckpt}")


if __name__ == "__main__":
    main()
