"""Paper §5.1 latency table + the paged-vs-dense KV cache sweep.

Layers, reported separately (DESIGN.md §9):

* modelled production latency per pool model (roofline-derived per-token
  time on the serving slice + lognormal tail) — mean and p99.9, matching the
  paper's 3.8s (78s) big / 1.2s (15s) small observation;
* measured CPU smoke-scale microbenchmarks of the real engine decode step
  (reduced configs) — real code path, not the production numbers;
* the **paged-vs-dense sweep**: the same classroom-style workload (prompts
  sharing a course-prompt prefix) served by the dense slot cache and by the
  paged pool + prefix trie at EQUAL HBM, across prefix-overlap ratios
  0 -> 0.9 — prefill tokens, admitted concurrency, wall time, and the
  copy-on-write / eviction counters (ISSUE 5 acceptance numbers).

CLI: ``--smoke`` runs the 0.5-overlap point with hard assertions (PR gate);
``--json PATH`` writes the full sweep as a nightly artifact.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

try:
    from benchmarks.common import Row
except ModuleNotFoundError:      # invoked as a script: repo root not on path
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import Row
from repro.core import build_bridge, Workload, WorkloadConfig

OVERLAPS = (0.0, 0.25, 0.5, 0.75, 0.9)


def run() -> List[Row]:
    rows: List[Row] = []
    wl = Workload(WorkloadConfig(n_conversations=2, turns_per_conversation=5))
    bridge = build_bridge(workload=wl, seed=0)
    rng = np.random.default_rng(0)
    for m in sorted(bridge.pool.list(), key=lambda m: m.active_params):
        lats = [m.usage_for(40, 90, rng=rng).latency for _ in range(4000)]
        rows.append((f"latency.model.{m.name}", 0.0,
                     f"mean={np.mean(lats):.2f}s p99.9={np.percentile(lats, 99.9):.1f}s "
                     f"(active={m.active_params/1e9:.1f}B)"))

    # real engine decode-step microbench (reduced configs, CPU)
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import init_model
    from repro.serving.engine import Engine
    for arch in ("qwen2-1.5b", "gemma3-27b", "zamba2-7b", "xlstm-350m"):
        cfg = configs.get_reduced(arch)
        eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
        cache = eng.new_cache(2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2, 1), jnp.int32)
        logits, cache = eng.decode(tok, pos, cache)     # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        n = 20
        for i in range(n):
            logits, cache = eng.decode(tok, pos + i + 1, cache)
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"latency.cpu_smoke.decode_step.{arch}", us,
                     "reduced-config real engine step"))

    # batched admission: a full slot refill pads the admitted prompts into
    # ONE prefill + ONE insert_slots (vs one prefill and one batched-pytree
    # rebuild per request before) — the derived column discloses the
    # engine-level prefill-call count for a 6-request mixed-length refill
    from repro.serving.scheduler import Request, Scheduler
    cfg = configs.get_reduced("qwen2-1.5b")
    eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
    prompts = [jnp.arange(4 + (i % 3), dtype=jnp.int32) + 3 for i in range(6)]
    sch = Scheduler(eng, n_slots=6)      # warm the padded-prefill compile
    for i, p in enumerate(prompts):
        sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=1))
    sch.step()
    sch2 = Scheduler(eng, n_slots=6)
    for i, p in enumerate(prompts):
        sch2.submit(Request(rid=i, user=f"w{i}", prompt=p, max_new=1))
    calls0 = eng.n_prefill_calls
    t0 = time.perf_counter()
    sch2._admit()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("latency.cpu_smoke.admit_refill.qwen2-1.5b", us,
                 f"6 mixed-length admits; prefill_calls="
                 f"{eng.n_prefill_calls - calls0} (was 6 pre-batching)"))
    rows += decode_sync_bench(eng)
    return rows


def decode_sync_bench(eng) -> List[Row]:
    """Per-token host sync vs polled done mask in ``Engine.generate``:
    the old loop forced ``bool(done.all())`` every step; the polled loop
    syncs every DONE_POLL_EVERY steps (and never, when EOS can't fire)."""
    import jax
    import jax.numpy as jnp
    from repro.serving.sampler import SamplerConfig, sample
    from repro.serving.engine import DONE_POLL_EVERY

    prompt = jnp.arange(8, dtype=jnp.int32)[None, :].repeat(4, 0) + 3
    max_new = 32

    def synced_loop():
        """The pre-ISSUE-5 semantics: one host round-trip per token."""
        cache = eng.new_cache(4, 64)
        logits, cache = eng.prefill(prompt, cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        key, done = jax.random.PRNGKey(0), jnp.zeros((4,), bool)
        for i in range(max_new):
            key, sub = jax.random.split(key)
            logits, cache = eng.decode(
                tok[:, None], jnp.full((4, 1), 8 + i, jnp.int32), cache)
            tok = sample(logits[:, -1], sub, SamplerConfig())
            done = done | (tok == -2)
            if bool(done.all()):
                break

    eng.generate(prompt, max_new=max_new, eos_id=-2)      # warm compile
    out: List[Row] = []
    t0 = time.perf_counter()
    synced_loop()
    t_sync = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.generate(prompt, max_new=max_new, eos_id=-2)      # polled path
    t_poll = time.perf_counter() - t0
    out.append(("latency.cpu_smoke.decode_sync.qwen2-1.5b",
                t_poll / max_new * 1e6,
                f"polled={t_poll*1e3:.1f}ms vs per-step-sync="
                f"{t_sync*1e3:.1f}ms over {max_new} steps "
                f"(poll every {DONE_POLL_EVERY})"))
    return out


def paged_sweep(overlaps=OVERLAPS, n_req: int = 12, prompt_len: int = 32,
                max_new: int = 8):
    """Dense slot cache vs paged pool + prefix trie at EQUAL HBM.

    ``overlap`` is the fraction of each prompt shared verbatim across the
    batch (course prompt / assignment scaffold); the dense baseline gets
    ``hbm_tokens / max_len`` slots, the paged side the same HBM in 8-token
    pages and enough slot headroom to show the page-budgeted concurrency.
    """
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import init_model
    from repro.serving.engine import Engine
    from repro.serving.scheduler import Request, Scheduler

    cfg = configs.get_reduced("qwen2-1.5b")
    eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
    page = 8
    dense_slots = 4
    hbm_pages = dense_slots * (64 // page)        # equal HBM budget
    rows: List[Row] = []
    points = []
    rng = np.random.default_rng(0)
    for overlap in overlaps:
        shared_len = int(round(overlap * prompt_len))
        shared = rng.integers(3, 90, shared_len).tolist()
        prompts = [jnp.asarray(
            shared + rng.integers(3, 90, prompt_len - shared_len).tolist(),
            jnp.int32) for _ in range(n_req)]

        def serve(sch, tag):
            for i, p in enumerate(prompts):
                sch.submit(Request(rid=i, user=f"{tag}{i}", prompt=p,
                                   max_new=max_new))
            t0 = time.perf_counter()
            done = sch.run_to_completion()
            dt = time.perf_counter() - t0
            assert len(done) == n_req
            return dt, {r.rid: r.generated for r in done}

        dense = Scheduler(eng, n_slots=dense_slots)
        t_dense, g_dense = serve(dense, f"d{overlap}")
        paged = Scheduler(eng, n_slots=n_req, paged=True, page_size=page,
                          n_pages=hbm_pages + 1)       # +1: pinned trash page
        t_paged, g_paged = serve(paged, f"p{overlap}")
        assert g_dense == g_paged, "paged outputs diverged from dense"
        point = {
            "overlap": overlap,
            "dense_prefill_tokens": dense.prefill_tokens,
            "paged_prefill_tokens": paged.prefill_tokens,
            "dense_peak_slots": dense.peak_live,
            "paged_peak_slots": paged.peak_live,
            "dense_wall_s": t_dense, "paged_wall_s": t_paged,
            "shared_tokens": paged.shared_tokens,
            "cow_forks": paged.pool.n_cow,
            "pages_evicted": paged.pool.n_evictions,
            "pages_allocated": paged.pool.n_allocs,
            "hbm_cache_tokens": hbm_pages * page,
        }
        points.append(point)
        rows.append((f"latency.paged_sweep.overlap{overlap}",
                     t_paged / n_req * 1e6,
                     f"prefill_tokens paged={paged.prefill_tokens} vs "
                     f"dense={dense.prefill_tokens}; peak_slots "
                     f"{paged.peak_live} vs {dense.peak_live} at equal HBM; "
                     f"shared={paged.shared_tokens}tok cow={paged.pool.n_cow}"))
        if overlap >= 0.5:
            # ISSUE 5 acceptance: measurably lower prefill cost + >= 2x the
            # concurrent slots at equal HBM, outputs bit-exact (checked above)
            assert paged.prefill_tokens < dense.prefill_tokens
            assert paged.peak_live >= 2 * dense.peak_live
    return rows, {"sweep": points, "n_req": n_req, "prompt_len": prompt_len,
                  "max_new": max_new, "page_size": page,
                  "dense_slots": dense_slots}


def spec_sweep(ks=(2, 4, 8), accept_p: float = 0.7, n_req: int = 8,
               prompt_len: int = 8, max_new: int = 48):
    """Speculative decoding on the paged engine vs plain paged decode.

    The draft is an ``OracleDraftEngine`` wrapping a genuinely smaller
    family sibling (1 layer, narrower) whose proposals match the verifier's
    greedy continuation with per-position probability ``accept_p`` — so the
    measured speedup corresponds to a CHOSEN acceptance rate, not whatever
    a random-weight draft happens to produce.  Outputs must stay bit-exact
    with the non-speculative baseline at every k.

    Two speed columns, reported separately (same split as the rest of this
    file): **verifier passes per emitted token** is the hardware-independent
    win — production decode is memory-bound, every big-model pass costs the
    same HBM sweep whether it verifies 1 or k+1 positions, so 1/passes-
    per-token IS the decode tokens/sec speedup there; the CPU smoke wall
    clock is also disclosed, but CPU matmuls are compute-bound (verify cost
    grows with k+1), so it understates the serving-regime gain.
    """
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import init_model
    from repro.serving.engine import DraftEngine, Engine, OracleDraftEngine
    from repro.serving.scheduler import Request, Scheduler

    cfg = configs.get_reduced("qwen2-1.5b")
    eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
    # smaller family sibling: same name/vocab (the compatibility gate's
    # contract), 1 layer and half the width
    dcfg = dataclasses.replace(cfg, n_layers=1, d_model=64)
    deng = Engine(dcfg, init_model(dcfg, jax.random.PRNGKey(1)),
                  max_len=64 + DraftEngine.HEADROOM)
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(3, 90, prompt_len).tolist(),
                           jnp.int32) for _ in range(n_req)]

    def serve(tag, draft=None, spec_k=4):
        sch = Scheduler(eng, n_slots=n_req, paged=True, page_size=8,
                        draft=draft, spec_k=spec_k)
        for i, p in enumerate(prompts):
            sch.submit(Request(rid=i, user=f"{tag}{i}", prompt=p,
                               max_new=max_new))
        steps = 0
        t0 = time.perf_counter()
        while sch.pending() or any(s is not None for s in sch.slots):
            sch.step()
            steps += 1
        dt = time.perf_counter() - t0
        sch.pool.check()
        return dt, steps, {r.rid: r.generated for r in sch.finished}, sch

    serve("warm")                                    # compile the baseline
    t_base, base_steps, g_base, _ = serve("base")
    n_tok = sum(len(g) for g in g_base.values())
    base_tps = n_tok / t_base
    rows: List[Row] = [("latency.spec_sweep.baseline", t_base / n_tok * 1e6,
                        f"plain paged decode: {base_steps} verifier steps, "
                        f"{base_tps:.0f} tok/s CPU-smoke")]
    points = []
    for k in ks:
        def mk_draft():
            return OracleDraftEngine(deng, n_slots=n_req, max_len=64,
                                     continuations=g_base,
                                     accept_p=accept_p, seed=2)
        serve(f"w{k}", draft=mk_draft(), spec_k=k)   # compile verify width
        t_spec, _, g_spec, sch = serve(f"s{k}", draft=mk_draft(), spec_k=k)
        assert g_spec == g_base, f"spec k={k} diverged from baseline"
        s = sch.spec_summary()
        assert s["enabled"], s["disabled_reason"]
        passes_per_tok = s["rounds"] / n_tok
        big_pass_speedup = base_steps / s["rounds"]
        spec_tps = n_tok / t_spec
        if k == 4:
            # acceptance gate: >= 2x decode tokens/sec in the memory-bound
            # serving regime == >= 2x fewer verifier passes per token
            assert big_pass_speedup >= 2.0, \
                f"spec k=4 speedup {big_pass_speedup:.2f}x < 2x"
            assert 0.3 < s["acceptance_rate"] < 0.6, \
                f"oracle acceptance drifted: {s['acceptance_rate']:.2f}"
        points.append({
            "k": k, "accept_p": accept_p,
            "acceptance_rate": s["acceptance_rate"],
            "tokens_per_round": s["tokens_per_round"],
            "rounds": s["rounds"], "baseline_steps": base_steps,
            "verifier_passes_per_token": passes_per_tok,
            "big_pass_speedup": big_pass_speedup,
            "draft_time_s": s["draft_time"], "verify_time_s": s["verify_time"],
            "spec_wall_s": t_spec, "baseline_wall_s": t_base,
            "spec_tok_s": spec_tps, "baseline_tok_s": base_tps,
        })
        rows.append((f"latency.spec_sweep.k{k}", t_spec / n_tok * 1e6,
                     f"batch tokens/round={s['tokens_per_round']:.2f} "
                     f"accept_p={accept_p} measured={s['acceptance_rate']:.2f} "
                     f"verifier passes/token={passes_per_tok:.2f} "
                     f"({big_pass_speedup:.1f}x fewer big-model passes = "
                     f"decode tok/s gain when memory-bound); CPU-smoke wall "
                     f"{spec_tps:.0f} vs {base_tps:.0f} tok/s"))
    return rows, {"spec_sweep": points, "n_req": n_req,
                  "prompt_len": prompt_len, "max_new": max_new,
                  "draft": {"n_layers": dcfg.n_layers,
                            "d_model": dcfg.d_model}}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one overlap point with hard assertions (PR gate)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the paged-vs-dense sweep as a JSON artifact")
    ap.add_argument("--full", action="store_true",
                    help="also run the §5.1 latency table rows")
    ap.add_argument("--spec", action="store_true",
                    help="run the speculative-decoding sweep instead of the "
                         "paged-vs-dense sweep")
    args = ap.parse_args()
    all_rows: List[Row] = list(run()) if args.full else []
    if args.spec:
        sweep_rows, artifact = spec_sweep(ks=(4,) if args.smoke else (2, 4, 8))
    else:
        sweep_rows, artifact = paged_sweep(
            overlaps=(0.5,) if args.smoke else OVERLAPS)
    all_rows += sweep_rows
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        artifact["rows"] = [{"name": n, "us_per_request": u, "derived": d}
                            for n, u, d in all_rows]
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.json}")
