"""Paper §5.1 latency table: big vs small model response latencies.

Two layers, reported separately (DESIGN.md §9):
* modelled production latency per pool model (roofline-derived per-token
  time on the serving slice + lognormal tail) — mean and p99.9, matching the
  paper's 3.8s (78s) big / 1.2s (15s) small observation;
* measured CPU smoke-scale microbenchmarks of the real engine decode step
  (reduced configs) — real code path, not the production numbers.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row
from repro.core import build_bridge, Workload, WorkloadConfig


def run() -> List[Row]:
    rows: List[Row] = []
    wl = Workload(WorkloadConfig(n_conversations=2, turns_per_conversation=5))
    bridge = build_bridge(workload=wl, seed=0)
    rng = np.random.default_rng(0)
    for m in sorted(bridge.pool.list(), key=lambda m: m.active_params):
        lats = [m.usage_for(40, 90, rng=rng).latency for _ in range(4000)]
        rows.append((f"latency.model.{m.name}", 0.0,
                     f"mean={np.mean(lats):.2f}s p99.9={np.percentile(lats, 99.9):.1f}s "
                     f"(active={m.active_params/1e9:.1f}B)"))

    # real engine decode-step microbench (reduced configs, CPU)
    import jax
    import jax.numpy as jnp
    from repro import configs
    from repro.models import init_model
    from repro.serving.engine import Engine
    for arch in ("qwen2-1.5b", "gemma3-27b", "zamba2-7b", "xlstm-350m"):
        cfg = configs.get_reduced(arch)
        eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
        cache = eng.new_cache(2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2, 1), jnp.int32)
        logits, cache = eng.decode(tok, pos, cache)     # compile
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        n = 20
        for i in range(n):
            logits, cache = eng.decode(tok, pos + i + 1, cache)
        jax.block_until_ready(logits)
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((f"latency.cpu_smoke.decode_step.{arch}", us,
                     "reduced-config real engine step"))

    # batched admission: a full slot refill pads the admitted prompts into
    # ONE prefill + ONE insert_slots (vs one prefill and one batched-pytree
    # rebuild per request before) — the derived column discloses the
    # engine-level prefill-call count for a 6-request mixed-length refill
    from repro.serving.scheduler import Request, Scheduler
    cfg = configs.get_reduced("qwen2-1.5b")
    eng = Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)), max_len=64)
    prompts = [jnp.arange(4 + (i % 3), dtype=jnp.int32) + 3 for i in range(6)]
    sch = Scheduler(eng, n_slots=6)      # warm the padded-prefill compile
    for i, p in enumerate(prompts):
        sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=1))
    sch.step()
    sch2 = Scheduler(eng, n_slots=6)
    for i, p in enumerate(prompts):
        sch2.submit(Request(rid=i, user=f"w{i}", prompt=p, max_new=1))
    calls0 = eng.n_prefill_calls
    t0 = time.perf_counter()
    sch2._admit()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("latency.cpu_smoke.admit_refill.qwen2-1.5b", us,
                 f"6 mixed-length admits; prefill_calls="
                 f"{eng.n_prefill_calls - calls0} (was 6 pre-batching)"))
    return rows
