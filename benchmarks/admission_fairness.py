"""Cross-user fairness of the admission front-end: Jain's index, queue wait,
batch fill.

Three scenarios over the planted workload (SIM-mode pool: cost/latency are
modelled, wall-clock is real):

* ``skew``   — two users with a 4:1 open-loop arrival skew share a fixed
  service capacity of 2 requests per round.  The same arrival trace is
  replayed twice: **naive FIFO** batching (take the next 2 arrivals in
  global order, whoever sent them) vs the **AdmissionController**'s
  per-user FIFO rotating round-robin.  Reports per-user completions and
  Jain's fairness index; the controller must be at least as fair as the
  baseline (acceptance invariant).
* ``load``   — 12 users submit open-loop bursts through a controller with
  ``max_batch=8``: formed batches must fill to ``max_batch`` (the batched
  embed/search/decode hot path actually engages), reported as a batch-size
  histogram plus p50/p99 queue wait.
* ``budget`` — one depleted-ledger user contends with funded users: it
  yields round-robin turns under contention (``budget_yields`` > 0) but
  still completes everything within the bounded-wait guarantee.

``--smoke`` shrinks the round counts for the PR gate (same asserts);
``--json PATH`` writes the full result dict — the nightly CI job uploads it
as a build artifact next to the proxy-throughput stage CDFs.
"""
from __future__ import annotations

import argparse
import collections
import json

from repro.core import (AdmissionController, ProxyRequest, ServiceType,
                        Workload, WorkloadConfig, build_bridge, jain_index,
                        jsonable)

ROUNDS_SKEW = 60
ROUNDS_SMOKE = 12
HEAVY_RATE, LIGHT_RATE = 4, 1          # 4:1 arrival skew
CAPACITY = 2                           # served requests per round (skew)
LOAD_USERS, LOAD_BURST, LOAD_MAX_BATCH = 12, 4, 8


def _workload():
    return Workload(WorkloadConfig(n_conversations=8, turns_per_conversation=8,
                                   seed=5))


def _req(wl, i: int, user: str,
         service: ServiceType = ServiceType.COST) -> ProxyRequest:
    q = wl.queries[i % len(wl.queries)]
    return ProxyRequest(prompt=q.text, user=user, conversation=user,
                        service_type=service, query=q, update_context=False)


def _arrivals(wl, rounds: int):
    """The shared open-loop trace: per round, HEAVY_RATE requests from the
    heavy user then LIGHT_RATE from the light one (arrival order)."""
    i = 0
    trace = []
    for _ in range(rounds):
        batch = []
        for _ in range(HEAVY_RATE):
            batch.append(_req(wl, i, "heavy")); i += 1
        for _ in range(LIGHT_RATE):
            batch.append(_req(wl, i, "light")); i += 1
        trace.append(batch)
    return trace


def run_skew(rounds: int = ROUNDS_SKEW) -> dict:
    wl = _workload()

    # -- naive FIFO baseline: global arrival order, no per-user discipline --
    bridge = build_bridge(workload=wl, seed=0)
    backlog = collections.deque()
    naive_done: collections.Counter = collections.Counter()
    for arriving in _arrivals(wl, rounds):
        backlog.extend(arriving)
        batch = [backlog.popleft() for _ in range(min(CAPACITY, len(backlog)))]
        for r in bridge.request_batch(batch):
            naive_done[r.request.user] += 1

    # -- AdmissionController: per-user FIFO, rotating round-robin -----------
    bridge = build_bridge(workload=wl, seed=0)
    ctrl = AdmissionController(bridge, max_batch=CAPACITY, max_wait=0.0)
    bridge.attach_admission(ctrl)
    adm_done: collections.Counter = collections.Counter()
    for arriving in _arrivals(wl, rounds):
        for r in arriving:
            ctrl.submit(r)
        for t in ctrl.dispatch():       # one batch per round = same capacity
            adm_done[t.req.user] += 1

    naive_jain = jain_index(list(naive_done.values()))
    adm_jain = jain_index(list(adm_done.values()))
    return {
        "rounds": rounds,
        "skew": f"{HEAVY_RATE}:{LIGHT_RATE}",
        "capacity_per_round": CAPACITY,
        "naive": {"completed": dict(naive_done), "jain": naive_jain},
        "admission": {"completed": dict(adm_done), "jain": adm_jain,
                      "stats": ctrl.stats()},
    }


def run_load(bursts: int = 3) -> dict:
    """Smart-cache traffic through the front-end: every formed batch must
    collapse to ONE embedder pass + ONE multi-query vector search (the hot
    path of PR 1), and under 12-user load batches must fill to max_batch."""
    wl = _workload()
    bridge = build_bridge(workload=wl, seed=0)
    from repro.core import CachedType
    for q in wl.queries[::2]:
        bridge.cache.put(q.text + " background facts. " * 5,
                         [(CachedType.CHUNK, q.text)], meta={"topic": q.topic})
    bridge.cache.embedder.n_calls = 0
    bridge.cache.store.n_searches = 0
    ctrl = AdmissionController(bridge, max_batch=LOAD_MAX_BATCH, max_wait=0.0)
    bridge.attach_admission(ctrl)
    i = 0
    for _ in range(bursts):
        for _ in range(LOAD_BURST):
            for u in range(LOAD_USERS):
                ctrl.submit(_req(wl, i, f"user{u}",
                                 service=ServiceType.SMART_CACHE))
                i += 1
        ctrl.drain()
    stats = ctrl.stats()
    stats["embed_calls"] = bridge.cache.embedder.n_calls
    stats["vector_searches"] = bridge.cache.store.n_searches
    assert stats["embed_calls"] == stats["batches"], \
        "batched embed hot path not engaged"
    return {"users": LOAD_USERS, "max_batch": LOAD_MAX_BATCH,
            "submitted": i, "stats": stats}


def run_budget(rounds: int = 8) -> dict:
    """One depleted user among funded contenders: deferred, never starved."""
    wl = _workload()
    bridge = build_bridge(workload=wl, seed=0)
    bridge.ledger.set_budget("depleted", 1.0)
    bridge.ledger.charge("depleted", 0.95)      # fraction left 0.05 -> tier 3
    ctrl = AdmissionController(bridge, max_batch=2, max_wait=0.0,
                               yield_tier=2, max_yields=3)
    bridge.attach_admission(ctrl)
    users = ["depleted", "fund0", "fund1", "fund2"]
    i = 0
    order = []                                  # completion order of users
    for _ in range(rounds):
        for u in users:
            ctrl.submit(_req(wl, i, u)); i += 1
        for t in ctrl.dispatch():
            order.append(t.req.user)
    for t in ctrl.drain():
        order.append(t.req.user)
    first_depleted = order.index("depleted") if "depleted" in order else -1
    return {"completion_order_head": order[:12],
            "first_depleted_completion": first_depleted,
            "depleted_completed": order.count("depleted"),
            "submitted_per_user": rounds,
            "stats": ctrl.stats()}


def run(smoke: bool = False) -> dict:
    rounds = ROUNDS_SMOKE if smoke else ROUNDS_SKEW
    skew = run_skew(rounds)
    load = run_load(bursts=1 if smoke else 3)
    budget = run_budget(rounds=6 if smoke else 12)

    # -- acceptance invariants (PR gate) ------------------------------------
    assert skew["admission"]["jain"] >= skew["naive"]["jain"] - 1e-9, \
        (skew["admission"]["jain"], skew["naive"]["jain"])
    hist = load["stats"]["batch_size_hist"]
    assert LOAD_MAX_BATCH in hist, f"batches never filled: {hist}"
    assert budget["depleted_completed"] == budget["submitted_per_user"], \
        "depleted user starved"
    assert budget["stats"]["budget_yields"] > 0, "depleted user never yielded"
    return {"skew": skew, "load": load, "budget": budget}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short rounds for the CI PR gate (same asserts)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full result dict as a JSON artifact")
    args = ap.parse_args()
    res = run(smoke=args.smoke)

    s = res["skew"]
    print(f"skew {s['skew']} x{s['rounds']} rounds, C={s['capacity_per_round']}: "
          f"naive jain={s['naive']['jain']:.3f} {s['naive']['completed']} | "
          f"admission jain={s['admission']['jain']:.3f} "
          f"{s['admission']['completed']}")
    st = res["load"]["stats"]
    print(f"load {res['load']['users']} users, max_batch="
          f"{res['load']['max_batch']}: hist={st['batch_size_hist']} "
          f"wait_p50={st['queue_wait_p50_s'] * 1e6:.0f}us "
          f"p99={st['queue_wait_p99_s'] * 1e6:.0f}us")
    b = res["budget"]
    print(f"budget: depleted completed {b['depleted_completed']}/"
          f"{b['submitted_per_user']} (first at #{b['first_depleted_completion']}, "
          f"{b['stats']['budget_yields']} yields) order={b['completion_order_head']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(jsonable(res), f, indent=2)
        print(f"wrote {args.json}")
