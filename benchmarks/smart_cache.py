"""Paper Fig 7a/7b: smart_cache — a small local model grounded by cached
factual material vs the small model alone vs the big model.

Claims validated:
* the small model alone hallucinates on hard factual queries (worst case
  ~1pt); smart_cache lifts the worst case to ~4pts (4x, Fig 7b);
* GPT4o-class remains better overall (Fig 7a) — the cache narrows the tail.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, timed
from repro.core import CachedType, Workload, WorkloadConfig, build_bridge

SMALL, BIG = "xlstm-350m", "grok-1-314b"   # Phi-3-analogue vs GPT4o-analogue

_WIKI = ("{t} is a widely discussed topic. The key fact about {t} is its "
         "documented history. Researchers agree {t} affects daily life. "
         "Encyclopedic sources record many details about {t}. ")


def run() -> List[Row]:
    wl = Workload(WorkloadConfig(n_conversations=17, turns_per_conversation=10,
                                 seed=9))
    # "old" generation: the small model is the paper's hallucination-prone
    # Phi-3-class model (no newer-generation capability bonus)
    bridge = build_bridge(workload=wl, seed=0, generation="old")
    bridge.cache.small_model = bridge.pool.get(SMALL)

    # §5.3 setup: last-10 queries per conversation; keep the factual ~30%
    factual = [q for q in wl.queries if q.factual]

    # delegated PUT of "wikipedia articles" on the workload's topics
    from repro.core.workload import TOPICS
    def populate():
        for t in {q.topic for q in factual}:
            doc = _WIKI.format(t=TOPICS[t]) * 4
            # key the chunks with topic-representative planted text so the
            # vector geometry lines up with queries on that topic
            reps = [q for q in wl.queries if q.topic == t][:2]
            for rep in reps:
                bridge.cache.put(doc, [(CachedType.CHUNK, rep.text)],
                                 meta={"topic": t})
    _, us_put = timed(populate)

    small_m = bridge.pool.get(SMALL)
    big_m = bridge.pool.get(BIG)
    q_small, q_big, q_cache, hits = [], [], [], 0
    for q in factual:
        q_small.append(wl.quality(q, small_m.effective_capability()))
        q_big.append(wl.quality(q, big_m.effective_capability()))
        hit, _, _, tq = bridge.cache.smart_get(q.text, query=q, workload=wl)
        if hit and tq is not None:
            hits += 1
            q_cache.append(tq)
        else:
            q_cache.append(q_small[-1])   # miss -> small model alone

    rows: List[Row] = [
        ("fig7a.small_alone", 0.0,
         f"mean={np.mean(q_small):.2f} min={np.min(q_small):.2f}"),
        ("fig7a.smart_cache", us_put / max(len(factual), 1),
         f"mean={np.mean(q_cache):.2f} min={np.min(q_cache):.2f} "
         f"hits={hits}/{len(factual)}"),
        ("fig7a.big_model", 0.0,
         f"mean={np.mean(q_big):.2f} min={np.min(q_big):.2f}"),
    ]
    hit_qualities = [tq for tq, h in zip(
        q_cache, range(len(q_cache)))]
    worst_small = float(np.min(q_small))
    # Fig 7b: the cache-hit subset
    sub_cache, sub_small = [], []
    for q, qs in zip(factual, q_small):
        hit, _, _, tq = bridge.cache.smart_get(q.text, query=q, workload=wl)
        if hit and tq is not None:
            sub_cache.append(tq)
            sub_small.append(qs)
    if sub_cache:
        ratio = float(np.min(sub_cache)) / max(float(np.min(sub_small)), 0.25)
        rows.append(("fig7b.worst_case_improvement", 0.0,
                     f"{float(np.min(sub_small)):.2f} -> "
                     f"{float(np.min(sub_cache)):.2f} "
                     f"(~{ratio:.1f}x; paper 1pt->4pts)"))
    return rows
