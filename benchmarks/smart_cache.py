"""Paper Fig 7a/7b: smart_cache — a small local model grounded by cached
factual material vs the small model alone vs the big model — plus the
retrieval scaling sweep (flat scan vs IVF probe, N = 1k -> 1M entries).

Claims validated:
* the small model alone hallucinates on hard factual queries (worst case
  ~1pt); smart_cache lifts the worst case to ~4pts (4x, Fig 7b);
* GPT4o-class remains better overall (Fig 7a) — the cache narrows the tail;
* semantic-cache GET latency grows sublinearly in store size on the IVF
  path while the flat scan grows linearly, at recall@4 >= 0.95 on planted
  geometry (the §3.5/§4 cost-model hot path).

CLI: ``--smoke`` shrinks the sweep for CI; ``--json PATH`` writes the
scaling artifact the nightly job uploads (BENCH_*.json retrieval tracking).
"""
from __future__ import annotations

import argparse
import json
import time
from typing import List

import numpy as np

try:
    from benchmarks.common import Row, timed
except ModuleNotFoundError:      # invoked as a script: repo root not on path
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import Row, timed
from repro.core import CachedType, Workload, WorkloadConfig, build_bridge
from repro.core.vector_store import VectorStore

SWEEP_SIZES = (1_000, 10_000, 100_000, 1_000_000)
# smoke span is 10x between the first and last IVF point so the sublinearity
# bound (rows-scored growth < 0.5x store growth) has sqrt(10)-vs-5 margin
SMOKE_SWEEP_SIZES = (1_000, 10_000, 100_000)
SWEEP_QUERIES = 16
SWEEP_REPEATS = 3

SMALL, BIG = "xlstm-350m", "grok-1-314b"   # Phi-3-analogue vs GPT4o-analogue

_WIKI = ("{t} is a widely discussed topic. The key fact about {t} is its "
         "documented history. Researchers agree {t} affects daily life. "
         "Encyclopedic sources record many details about {t}. ")


def run() -> List[Row]:
    wl = Workload(WorkloadConfig(n_conversations=17, turns_per_conversation=10,
                                 seed=9))
    # "old" generation: the small model is the paper's hallucination-prone
    # Phi-3-class model (no newer-generation capability bonus)
    bridge = build_bridge(workload=wl, seed=0, generation="old")
    bridge.cache.small_model = bridge.pool.get(SMALL)

    # §5.3 setup: last-10 queries per conversation; keep the factual ~30%
    factual = [q for q in wl.queries if q.factual]

    # delegated PUT of "wikipedia articles" on the workload's topics
    from repro.core.workload import TOPICS
    def populate():
        for t in {q.topic for q in factual}:
            doc = _WIKI.format(t=TOPICS[t]) * 4
            # key the chunks with topic-representative planted text so the
            # vector geometry lines up with queries on that topic
            reps = [q for q in wl.queries if q.topic == t][:2]
            for rep in reps:
                bridge.cache.put(doc, [(CachedType.CHUNK, rep.text)],
                                 meta={"topic": t})
    _, us_put = timed(populate)

    small_m = bridge.pool.get(SMALL)
    big_m = bridge.pool.get(BIG)
    q_small, q_big, q_cache, hits = [], [], [], 0
    for q in factual:
        q_small.append(wl.quality(q, small_m.effective_capability()))
        q_big.append(wl.quality(q, big_m.effective_capability()))
        hit, _, _, tq = bridge.cache.smart_get(q.text, query=q, workload=wl)
        if hit and tq is not None:
            hits += 1
            q_cache.append(tq)
        else:
            q_cache.append(q_small[-1])   # miss -> small model alone

    rows: List[Row] = [
        ("fig7a.small_alone", 0.0,
         f"mean={np.mean(q_small):.2f} min={np.min(q_small):.2f}"),
        ("fig7a.smart_cache", us_put / max(len(factual), 1),
         f"mean={np.mean(q_cache):.2f} min={np.min(q_cache):.2f} "
         f"hits={hits}/{len(factual)}"),
        ("fig7a.big_model", 0.0,
         f"mean={np.mean(q_big):.2f} min={np.min(q_big):.2f}"),
    ]
    hit_qualities = [tq for tq, h in zip(
        q_cache, range(len(q_cache)))]
    worst_small = float(np.min(q_small))
    # Fig 7b: the cache-hit subset
    sub_cache, sub_small = [], []
    for q, qs in zip(factual, q_small):
        hit, _, _, tq = bridge.cache.smart_get(q.text, query=q, workload=wl)
        if hit and tq is not None:
            sub_cache.append(tq)
            sub_small.append(qs)
    if sub_cache:
        ratio = float(np.min(sub_cache)) / max(float(np.min(sub_small)), 0.25)
        rows.append(("fig7b.worst_case_improvement", 0.0,
                     f"{float(np.min(sub_small)):.2f} -> "
                     f"{float(np.min(sub_cache)):.2f} "
                     f"(~{ratio:.1f}x; paper 1pt->4pts)"))
    return rows


# -- retrieval scaling sweep ---------------------------------------------------
def _planted_store_vectors(n: int, dim: int, rng) -> np.ndarray:
    """Clustered unit vectors mimicking the planted workload's topic
    geometry (queries for a topic land near that topic's stored keys)."""
    n_clusters = max(16, int(np.sqrt(n)) // 4)
    cent = rng.normal(size=(n_clusters, dim)).astype(np.float32)
    cent /= np.maximum(np.linalg.norm(cent, axis=1, keepdims=True), 1e-9)
    pts = cent[rng.integers(0, n_clusters, n)] + \
        0.15 * rng.normal(size=(n, dim)).astype(np.float32)
    return (pts / np.maximum(np.linalg.norm(pts, axis=1, keepdims=True),
                             1e-9)).astype(np.float32)


def scaling_sweep(sizes=SWEEP_SIZES, dim: int = 64,
                  n_queries: int = SWEEP_QUERIES,
                  repeats: int = SWEEP_REPEATS):
    """Flat scan vs IVF probe across store sizes.

    Returns (rows, artifact): CSV rows plus the JSON-able record the nightly
    job uploads.  Each point reports best-of-``repeats`` search wall-time for
    both backends, recall@4 of IVF vs the flat ground truth on perturbed
    planted queries, rows scored per query, and index build time.
    """
    rng = np.random.default_rng(17)
    rows: List[Row] = []
    points = []
    for n in sizes:
        vecs = _planted_store_vectors(n, dim, rng)
        ivf = VectorStore(dim=dim)                      # default knobs
        flat = VectorStore(dim=dim, crossover=1 << 62)  # never builds an index
        ivf.add(vecs, np.arange(n))
        flat.add(vecs, np.arange(n))
        qs = vecs[rng.choice(n, n_queries, replace=False)] + \
            0.05 * rng.normal(size=(n_queries, dim)).astype(np.float32)

        def best(store):
            t = float("inf")
            for _ in range(repeats):
                t0 = time.perf_counter()
                store.search(qs, top_k=4)
                t = min(t, time.perf_counter() - t0)
            return t

        t_flat = best(flat)
        t_ivf = best(ivf)
        got = ivf.search(qs, top_k=4)
        want = flat.search(qs, top_k=4)
        recall = float(np.mean([
            len({h.index for h in g} & {h.index for h in w}) / 4
            for g, w in zip(got, want)]))
        st = ivf.index_stats()
        searches = repeats + 1
        rows_per_q = st["n_shortlist_rows"] / max(st["n_ivf_searches"], 1) \
            / n_queries if st["backend"] == "ivf" else float(n)
        point = {
            "n": n, "flat_us": t_flat * 1e6, "ivf_us": t_ivf * 1e6,
            "speedup": t_flat / t_ivf, "recall_at_4": recall,
            "backend": st["backend"], "n_lists": st["n_lists"],
            "nprobe": st["nprobe"], "rows_scored_per_query": rows_per_q,
            "build_s": st["last_build_s"], "searches": searches,
        }
        points.append(point)
        rows.append((f"smart_cache.scaling.N{n}", t_ivf * 1e6 / n_queries,
                     f"flat={t_flat*1e3:.2f}ms ivf={t_ivf*1e3:.2f}ms "
                     f"speedup={t_flat/t_ivf:.1f}x recall@4={recall:.3f} "
                     f"rows/q={rows_per_q:.0f}/{n} backend={st['backend']}"))
        if st["backend"] == "ivf":
            assert recall >= 0.95, (n, recall)
    # the separation claim: above the crossover the IVF path scores a
    # vanishing fraction of the store while the flat scan touches all of it
    ivf_pts = [p for p in points if p["backend"] == "ivf"]
    if len(ivf_pts) >= 2:
        lo, hi = ivf_pts[0], ivf_pts[-1]
        work_growth = (hi["rows_scored_per_query"] /
                       max(lo["rows_scored_per_query"], 1.0))
        size_growth = hi["n"] / lo["n"]
        assert work_growth < 0.5 * size_growth, (work_growth, size_growth)
        rows.append(("smart_cache.scaling.sublinearity", 0.0,
                     f"rows-scored growth {work_growth:.1f}x over a "
                     f"{size_growth:.0f}x store ({hi['speedup']:.1f}x faster "
                     f"than flat at N={hi['n']})"))
    return rows, {"sweep": points, "dim": dim, "n_queries": n_queries}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small store sizes, CI-friendly")
    ap.add_argument("--json", metavar="PATH",
                    help="write the scaling sweep as a JSON artifact")
    ap.add_argument("--fig7", action="store_true",
                    help="also run the Fig 7 quality benchmark")
    args = ap.parse_args()
    all_rows: List[Row] = list(run()) if args.fig7 else []
    sweep_rows, artifact = scaling_sweep(
        sizes=SMOKE_SWEEP_SIZES if args.smoke else SWEEP_SIZES)
    all_rows += sweep_rows
    for name, us, derived in all_rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        artifact["rows"] = [{"name": n, "us_per_query": u, "derived": d}
                            for n, u, d in all_rows]
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=2)
        print(f"wrote {args.json}")
