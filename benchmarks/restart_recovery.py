"""Restart recovery: journal replay cost, compaction bound, and warm-start.

Two scenarios (all assert — the CI PR gate runs ``--smoke``):

* ``scaling`` — grow the ledger WAL to N records with compaction disabled
  and with it enabled.  Uncompacted recovery replays all N records;
  compacted recovery loads the snapshot plus a tail bounded by
  ``ledger_snapshot_every`` — the replayed-record count is asserted against
  that bound, recovered balances must equal the live ledger's exactly, and
  at the largest N the compacted recovery must be faster than replaying
  full history (recovery cost scales with snapshot + tail, not lifetime).

* ``kill_restart`` — a bridge with a seeded persistent cache is killed
  mid-workload at a named crash point (``proxy.finalize.pre``).  A restarted
  bridge over the same directory retries every request with the same
  idempotency keys: total spend must equal the continuous (never-crashed)
  run to the cent, the cache hit count must match it (warm start), no holds
  may be stranded — and a cold pod (no durable state) must demonstrably hit
  less than the warm one.

``--smoke`` shrinks the journal sizes and workload for the PR gate (same
asserts); ``--json PATH`` writes the full result dict for the nightly
artifact.
"""
from __future__ import annotations

import argparse
import json
import tempfile

from repro.core import (CachedType, Constraints, Durability, Preference,
                        ProxyRequest, SimulatedCrash, Workload,
                        WorkloadConfig, build_bridge, jsonable)

NS, NS_SMOKE = (1000, 4000, 16000), (1000, 4000)
N_REQ, N_REQ_SMOKE = 24, 12
COMPACT_EVERY = 512
N_USERS = 4


# -- scenario 1: recovery time vs journal length -------------------------------

def _grow_and_recover(n: int, snapshot_every: int) -> dict:
    """Append ``n`` journaled charges, kill (no final snapshot), recover."""
    with tempfile.TemporaryDirectory() as tmp:
        d = Durability(tmp, ledger_snapshot_every=snapshot_every)
        led = d.open_ledger()
        for i in range(n):
            led.charge(f"u{i % N_USERS}", 0.001, key=f"k{i}")
        live = {u: led.spent(u) for u in
                (f"u{j}" for j in range(N_USERS))}
        d.close(final_snapshot=False)

        d2 = Durability(tmp, ledger_snapshot_every=snapshot_every)
        led2 = d2.open_ledger()
        rec = dict(led2.recovery)
        for u, s in live.items():
            assert abs(led2.spent(u) - s) < 1e-9, (u, led2.spent(u), s)
        d2.close(final_snapshot=False)
    return rec


def run_scaling(ns=NS) -> dict:
    rows = []
    for n in ns:
        full = _grow_and_recover(n, snapshot_every=10**9)   # never compacts
        comp = _grow_and_recover(n, snapshot_every=COMPACT_EVERY)
        # -- acceptance invariants (PR gate) --------------------------------
        assert full["replayed_records"] == n + 0, full     # whole history
        assert comp["replayed_records"] <= COMPACT_EVERY, comp
        assert comp["snapshot_seq"] > 0, comp
        rows.append({"n": n,
                     "uncompacted_s": full["recovery_time_s"],
                     "uncompacted_replayed": full["replayed_records"],
                     "compacted_s": comp["recovery_time_s"],
                     "compacted_replayed": comp["replayed_records"],
                     "compacted_snapshot_seq": comp["snapshot_seq"]})
    big = rows[-1]
    # recovery cost is snapshot + tail, not total history: at the largest
    # journal the compacted restart must beat full replay outright
    assert big["compacted_s"] < big["uncompacted_s"], big
    return {"compact_every": COMPACT_EVERY, "rows": rows}


# -- scenario 2: kill mid-workload, restart, retry -----------------------------

def _workload() -> Workload:
    return Workload(WorkloadConfig(n_conversations=6, turns_per_conversation=8,
                                   seed=23))


def _req(wl, i: int) -> ProxyRequest:
    q = wl.queries[i % len(wl.queries)]
    return ProxyRequest(prompt=q.text, user=f"u{i % N_USERS}", query=q,
                        request_id=f"rr-{i}", update_context=False,
                        preference=Preference.COST_FIRST,
                        constraints=Constraints(allow_cache=True,
                                                allow_prefetch=False))


def _seed_cache(bridge, wl) -> None:
    for q in wl.queries[::2]:
        bridge.cache.put(q.text + " grounding facts. " * 4,
                         [(CachedType.CHUNK, q.text)],
                         meta={"topic": q.topic}, rid=f"seed-{q.qid}")


def _drive(bridge, wl, n_req: int) -> dict:
    spent, hits = 0.0, 0
    for i in range(n_req):
        r = bridge.request(_req(wl, i))
        hits += bool(r.metadata.cache_hit)
    for j in range(N_USERS):
        spent += bridge.ledger.spent(f"u{j}")
    return {"spent": spent, "hits": hits}


def run_kill_restart(n_req: int = N_REQ) -> dict:
    wl = _workload()

    # the continuous run the kill/restart/retry must reproduce
    with tempfile.TemporaryDirectory() as tmp:
        b = build_bridge(workload=wl, data_dir=tmp)
        _seed_cache(b, wl)
        base = _drive(b, wl, n_req)
        b.close()
    assert base["spent"] > 0 and base["hits"] > 0, base

    with tempfile.TemporaryDirectory() as tmp:
        d = Durability(tmp)
        d.crash.arm("proxy.finalize.pre", at=n_req // 2)
        b = build_bridge(workload=wl, durability=d)
        killed = False
        try:
            _seed_cache(b, wl)
            _drive(b, wl, n_req)
        except SimulatedCrash:
            killed = True
        assert killed, "crash point never fired"

        # restart over the surviving files; client retries everything
        d2 = Durability(tmp)
        b2 = build_bridge(workload=wl, durability=d2)
        recovery = {"ledger": dict(b2.ledger.recovery),
                    "cache": dict(b2.cache.persist.recovery)}
        _seed_cache(b2, wl)                      # rid-keyed: no duplicates
        warm = _drive(b2, wl, n_req)
        stranded = {u: h for u, h in b2.ledger._held.items()
                    if abs(h) > 1e-9}
        b2.close()

    # a pod with no durable state starts cold: the seeds died with it
    cold_bridge = build_bridge(workload=wl)
    cold = _drive(cold_bridge, wl, n_req)
    cold_bridge.close()

    # -- acceptance invariants (PR gate) ------------------------------------
    assert abs(warm["spent"] - base["spent"]) < 1e-9, (warm, base)
    assert warm["hits"] == base["hits"], (warm, base)     # same hit-rate
    assert not stranded, stranded
    assert cold["hits"] < warm["hits"], (cold, warm)
    assert recovery["cache"]["rows"] > 0, recovery
    return {"n_req": n_req, "baseline": base, "warm": warm, "cold": cold,
            "recovery": recovery,
            "warm_hit_rate": warm["hits"] / n_req,
            "cold_hit_rate": cold["hits"] / n_req}


def run(smoke: bool = False) -> dict:
    ns = NS_SMOKE if smoke else NS
    n_req = N_REQ_SMOKE if smoke else N_REQ
    return {"scaling": run_scaling(ns),
            "kill_restart": run_kill_restart(n_req)}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small journals/workload for the CI PR gate "
                         "(same asserts)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full result dict as a JSON artifact")
    args = ap.parse_args()
    res = run(smoke=args.smoke)

    print(f"recovery scaling (compact every "
          f"{res['scaling']['compact_every']} records):")
    for row in res["scaling"]["rows"]:
        print(f"  n={row['n']:>6}: full replay {row['uncompacted_s']*1e3:7.1f}ms"
              f" ({row['uncompacted_replayed']} records) | snapshot+tail "
              f"{row['compacted_s']*1e3:6.1f}ms "
              f"({row['compacted_replayed']} records)")
    k = res["kill_restart"]
    print(f"kill@mid-workload: spend {k['warm']['spent']:.6f} == baseline "
          f"{k['baseline']['spent']:.6f} | hit-rate warm "
          f"{k['warm_hit_rate']:.2f} == baseline "
          f"{k['baseline']['hits'] / k['n_req']:.2f} > cold "
          f"{k['cold_hit_rate']:.2f}")
    print(f"  ledger recovery: {k['recovery']['ledger']}")
    print(f"  cache recovery:  {k['recovery']['cache']}")
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(jsonable(res), fp, indent=2)
        print(f"wrote {args.json}")
