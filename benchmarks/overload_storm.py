"""Overload storm: goodput under 1x-4x offered load, with and without brownout.

A seeded, virtual-clock storm generator drives the admission front-end the
way the paper's burst deployments (WhatsApp Q&A, classroom spikes) would: a
Poisson arrival process at a multiple of single-pod capacity, mixed latency
deadlines, one shared SIM-mode bridge.  The serving side is modelled as a
batch server — one formed batch occupies the (virtual) pod for ``T_BATCH``
seconds of decode when it contains real model work, near-zero when brownout
turned it into declines/cache-only — so every run replays exactly from its
seed and the whole sweep takes seconds of wall time.

Scenarios (all assert — the CI PR gate runs ``--smoke``):

* ``storm``    — the controlled pod at 1x and 4x offered load.  Goodput
  (deadline-met real completions/s) at 4x must hold within 10% of the 1x
  value; accepted-request p95 end-to-end latency must stay within 2x the
  1x p95; the brownout cycle NORMAL -> SHED -> NORMAL must be visible in
  ``stats()["overload"]`` with a bounded transition count (hysteresis, no
  flapping); and every ledger hold must be back to zero.
* ``collapse`` — the SAME 4x storm with the controller disabled: unbounded
  queueing pushes waits past every deadline and goodput collapses, which is
  the counterfactual that proves the layer earns its keep.
* ``shed_free``— a pod forced to SHED refuses every submit with a
  structured ``OverloadError`` (positive ``retry_after``) and the ledger
  shows zero spend and zero stranded holds: shed work never charges.

``--smoke`` shrinks the storm duration for the PR gate (same asserts);
``--json PATH`` writes the full result dict for the nightly artifact.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (AdmissionController, BrownoutController, Constraints,
                        LoadMonitor, OverloadError, Preference, ProxyRequest,
                        Workload, WorkloadConfig, build_bridge, jsonable)

MAX_BATCH = 8
T_FIX = 0.04           # virtual s of per-batch overhead (formation, prefill launch)
T_REQ = 0.12           # virtual s of decode per REAL request in the batch
#: sustainable real-request throughput at full batches: declines/cache-only
#: tickets ride along at ~zero marginal service
CAPACITY = MAX_BATCH / (T_FIX + T_REQ * MAX_BATCH)
DURATION, DURATION_SMOKE = 40.0, 15.0
COOLDOWN = 20.0        # 1x tail after the storm so de-escalation is visible
N_USERS = 12
#: storm-tuned monitor targets: saturation here is ~4 batches of backlog /
#: ~4s realized wait — brownout engages before the queue can push an
#: accepted request's wait past what its deadline can absorb, but late
#: enough that full batches of real work keep the pod near capacity
TARGETS = {"queue_depth": 32.0, "queue_wait": 4.0}
#: narrowed CACHE_PREFERRED band + shorter dwell: under a sustained storm
#: the controller duty-cycles accept<->shed, and time spent in the
#: cache-only band turns accepted slots into declines that displace real
#: work from batches — keep that band thin and recover fast
ENTER, EXIT, DWELL = (0.5, 0.9, 1.0), (0.35, 0.7, 0.85), 0.5


class VirtualClock:
    def __init__(self):
        self.t = 0.0

    def now(self) -> float:
        return self.t

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


def _workload() -> Workload:
    return Workload(WorkloadConfig(n_conversations=8, turns_per_conversation=8,
                                   seed=5))


def _arrivals(rng, rate: float, t0: float, t1: float) -> list:
    """Poisson arrival times in [t0, t1) with per-request deadline mix:
    mostly relaxed (6s), a tight slice (3s) that exercises the
    deadline-infeasibility shed under backlog."""
    out, t = [], t0
    while True:
        t += rng.exponential(1.0 / rate)
        if t >= t1:
            return out
        out.append((t, 4.0 if rng.random() < 0.25 else 8.0))


def _req(wl, i: int, deadline: float) -> ProxyRequest:
    q = wl.queries[i % len(wl.queries)]
    return ProxyRequest(
        prompt=q.text, user=f"u{i % N_USERS}", conversation=f"u{i % N_USERS}",
        query=q, update_context=False,
        constraints=Constraints(max_latency=deadline, allow_cache=False,
                                allow_prefetch=False),
        preference=Preference.COST_FIRST)


def _real(ticket) -> bool:
    r = ticket.response
    return (r is not None
            and r.metadata.model_used not in ("none", "timeout", "error"))


def _run_storm(mult: float, controlled: bool, duration: float,
               seed: int = 7) -> dict:
    """One pod under ``mult``x offered load for ``duration`` virtual
    seconds, then a 1x cooldown tail, then drain."""
    wl = _workload()
    bridge = build_bridge(workload=wl, seed=0)
    clock = VirtualClock()
    if controlled:
        bridge.enable_overload(
            clock=clock.now, monitor=LoadMonitor(targets=TARGETS),
            brownout=BrownoutController(clock=clock.now, enter=ENTER,
                                        exit=EXIT, min_dwell=DWELL))
    adm = AdmissionController(bridge, max_batch=MAX_BATCH, max_wait=0.05,
                              clock=clock.now, max_queue_depth=32,
                              max_user_depth=8)
    bridge.attach_admission(adm)
    rng = np.random.default_rng(seed)
    plan = (_arrivals(rng, mult * CAPACITY, 0.0, duration)
            + _arrivals(rng, 1.0 * CAPACITY, duration, duration + COOLDOWN))

    shed = {}
    done = []           # (ticket, deadline) of every dispatched request
    accepted = 0
    free_at = 0.0
    i = 0
    while i < len(plan) or adm.pending():
        next_arr = plan[i][0] if i < len(plan) else float("inf")
        if adm.pending() and free_at <= next_arr:
            clock.advance_to(free_at)
            batch = adm.dispatch()
            n_real = sum(1 for t in batch if t.error is None and _real(t))
            free_at = clock.t + T_FIX + T_REQ * n_real
            done.extend(batch)
        else:
            clock.advance_to(next_arr)
            t_arr, deadline = plan[i]
            try:
                ticket = adm.submit(_req(wl, i, deadline))
                ticket.x_deadline = deadline
                accepted += 1
            except OverloadError as e:
                assert e.retry_after > 0, e.retry_after
                shed[e.reason] = shed.get(e.reason, 0) + 1
            i += 1

    lats, good = [], 0
    for t in done:
        if t.error is not None:
            shed["deadline_expired_d"] = shed.get("deadline_expired_d", 0) + 1
            continue
        if not _real(t):
            continue
        total = t.queue_wait + t.response.metadata.usage.latency
        lats.append(total)
        if total <= getattr(t, "x_deadline", float("inf")):
            good += 1
    horizon = duration + COOLDOWN
    snap = bridge.stats()["overload"]
    held = dict(getattr(bridge.ledger, "_held", {}))
    return {
        "mult": mult, "controlled": controlled, "offered": len(plan),
        "accepted": accepted, "shed": shed,
        "real_completions": len(lats), "goodput_rps": good / horizon,
        "served_rps": len(lats) / horizon,
        "p95_s": float(np.percentile(lats, 95)) if lats else 0.0,
        "p50_s": float(np.percentile(lats, 50)) if lats else 0.0,
        "levels_seen": sorted({tr["to"] for tr in
                               snap["brownout"]["transitions"]}),
        "final_level": snap["level"],
        "n_transitions": snap["brownout"]["n_transitions"],
        "stranded_holds": {u: h for u, h in held.items() if abs(h) > 1e-9},
        "overload": snap,
        "admission": bridge.stats()["admission"],
    }


def run_storm(duration: float = DURATION) -> dict:
    base = _run_storm(1.0, controlled=True, duration=duration)
    peak = _run_storm(4.0, controlled=True, duration=duration)
    # -- acceptance invariants (PR gate) ------------------------------------
    assert peak["goodput_rps"] >= 0.9 * base["goodput_rps"], \
        (peak["goodput_rps"], base["goodput_rps"])
    assert peak["p95_s"] <= 2.0 * max(base["p95_s"], 1e-9), \
        (peak["p95_s"], base["p95_s"])
    assert "shed" in peak["levels_seen"], peak["levels_seen"]
    assert peak["final_level"] == "normal", peak["final_level"]
    # hysteresis: the dwell rate-limits transitions — a flapping controller
    # would transition per observation (hundreds per virtual second)
    assert peak["n_transitions"] <= 2 * (duration + COOLDOWN), \
        peak["n_transitions"]
    for row in (base, peak):
        assert not row["stranded_holds"], row["stranded_holds"]
    assert peak["overload"]["shed_total"] > 0, "4x storm never shed"
    return {"capacity_rps": CAPACITY, "base": base, "peak": peak}


def run_collapse(duration: float = DURATION, controlled_goodput: float = None
                 ) -> dict:
    off = _run_storm(4.0, controlled=False, duration=duration)
    # -- acceptance invariants (PR gate) ------------------------------------
    assert off["shed"] == {}, off["shed"]          # nothing ever refused
    if controlled_goodput is not None:
        assert off["goodput_rps"] <= 0.6 * controlled_goodput, \
            (off["goodput_rps"], controlled_goodput)
    return off


def run_shed_free(n: int = 50) -> dict:
    """A pod pinned at SHED refuses everything, charges nothing."""
    wl = _workload()
    bridge = build_bridge(workload=wl, seed=0)
    ov = bridge.enable_overload()
    ov.monitor.observe("queue_depth", 10_000)      # force pressure >> 1
    raised = 0
    for i in range(n):
        try:
            bridge.admission.submit(_req(wl, i, 6.0))
        except OverloadError as e:
            assert e.retry_after > 0 and e.reason == "load_shed", vars(e)
            raised += 1
    summary = bridge.ledger.summary()
    spent = sum(u["spent"] for u in summary.values())
    held = sum(getattr(bridge.ledger, "_held", {}).values())
    # -- acceptance invariants (PR gate) ------------------------------------
    assert raised == n, (raised, n)
    assert spent == 0.0, spent
    assert abs(held) < 1e-9, held
    return {"n": n, "raised": raised, "ledger_spent": spent,
            "holds_outstanding": held,
            "shed": bridge.stats()["overload"]["shed"]}


def run(smoke: bool = False) -> dict:
    duration = DURATION_SMOKE if smoke else DURATION
    storm = run_storm(duration)
    collapse = run_collapse(duration,
                            controlled_goodput=storm["peak"]["goodput_rps"])
    return {"duration_s": duration, "storm": storm, "collapse": collapse,
            "shed_free": run_shed_free()}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short storm for the CI PR gate (same asserts)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full result dict as a JSON artifact")
    args = ap.parse_args()
    res = run(smoke=args.smoke)

    s = res["storm"]
    print(f"capacity {s['capacity_rps']:.1f} req/s | goodput "
          f"1x={s['base']['goodput_rps']:.2f} "
          f"4x={s['peak']['goodput_rps']:.2f} req/s | p95 "
          f"{s['base']['p95_s']:.2f}s -> {s['peak']['p95_s']:.2f}s")
    print(f"4x brownout: levels={s['peak']['levels_seen']} "
          f"final={s['peak']['final_level']} "
          f"transitions={s['peak']['n_transitions']} "
          f"shed={s['peak']['shed']}")
    c = res["collapse"]
    print(f"uncontrolled 4x: goodput {c['goodput_rps']:.2f} req/s "
          f"(p95 {c['p95_s']:.1f}s) — collapse vs "
          f"{s['peak']['goodput_rps']:.2f} controlled")
    f = res["shed_free"]
    print(f"forced SHED: {f['raised']}/{f['n']} refused, "
          f"ledger spent {f['ledger_spent']:.4f}, "
          f"holds {f['holds_outstanding']:.4f}")
    if args.json:
        with open(args.json, "w") as fp:
            json.dump(jsonable(res), fp, indent=2)
        print(f"wrote {args.json}")
