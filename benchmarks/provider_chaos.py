"""Provider-fleet chaos sweep: availability, tail latency, cost overhead.

Three scenarios over the planted workload (SIM-mode pool; failures, latency
and the clock are all modelled, so every run replays exactly from its seed):

* ``availability`` — every provider gets a 20% injected error rate.  The
  same request trace runs against the **static ladder** (``max_attempts=1``:
  the routed model either answers or the request fails — the paper's
  quality/cost selection with no failure domain) and against **fleet
  routing** (bounded retry-against-healthy with backoff).  Fleet
  availability must reach >= 99% while the static ladder sits near the 80%
  direct hit rate.  The run also checks ledger conservation: every charge
  equals the sum of response usage costs (retries never double-charge), and
  a finite-budget user is never overdrawn.
* ``hedge``    — latency-first intents against a provider with a stall tail
  (12% of requests hit a 10s timeout).  Replayed twice from the same chaos
  seed, hedging off vs on: once the primary exceeds its tracked p95, a
  second request fires at the next-healthiest provider and the winner is
  kept.  Hedging must cut realised p95 latency; the duplicated spend is
  disclosed as ``wasted_hedge_cost``, never charged to the ledger.
* ``outage``   — the routed provider goes hard-down mid-run: its breaker
  opens (traffic shifts to healthy providers, availability holds), then
  recovers through half-open probes after the outage ends.

``--smoke`` shrinks request counts for the CI PR gate (same asserts);
``--json PATH`` writes the full result dict — the nightly job uploads it
next to the fairness/latency artifacts.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (CircuitBreaker, Constraints, FaultSpec, Preference,
                        ProxyRequest, ServiceType, Workload, WorkloadConfig,
                        build_bridge, jsonable)

ERROR_RATE = 0.2
N_AVAIL, N_AVAIL_SMOKE = 240, 80
N_HEDGE, N_HEDGE_SMOKE = 200, 90
N_OUTAGE = 72


def _workload():
    return Workload(WorkloadConfig(n_conversations=8, turns_per_conversation=8,
                                   seed=5))


def _req(wl, i: int, user: str = "chaos", **kw) -> ProxyRequest:
    q = wl.queries[i % len(wl.queries)]
    return ProxyRequest(prompt=q.text, user=user, conversation=user,
                        service_type=ServiceType.COST, query=q,
                        update_context=False, **kw)


def _inject_all(bridge, spec: FaultSpec) -> None:
    for m in bridge.pool.list():
        bridge.providers.configure(m.name, spec)


def run_availability(n: int = N_AVAIL) -> dict:
    wl = _workload()
    spec = FaultSpec(error_rate=ERROR_RATE)

    def trace(max_attempts: int) -> dict:
        bridge = build_bridge(workload=wl, seed=0)
        bridge.providers.max_attempts = max_attempts
        # finite-budget canary: ~6 cheap answers' worth, so the intent path
        # genuinely hits the decline boundary mid-run under chaos
        unit = bridge.adapter.estimate_answer(
            bridge.pool.cheapest(), wl.queries[0].text,
            query=wl.queries[0]).cost
        bridge.ledger.set_budget("capped", 6 * unit)
        _inject_all(bridge, spec)
        served = 0
        charged = 0.0
        declines = 0
        attempts = []
        for i in range(n):
            r = bridge.request(_req(wl, i))
            if r.metadata.model_used != "error":
                served += 1
            charged += r.metadata.usage.cost
            attempts.append(r.metadata.provider_attempts)
            if i % 5 == 0:
                # intent-path request from the capped user: compiled holds +
                # affordability-filtered fallback = never overdrawn, even
                # when a retry answers with a pricier provider
                rc = bridge.request(_req(
                    wl, i, user="capped",
                    constraints=Constraints(allow_cache=False,
                                            allow_prefetch=False),
                    preference=Preference.COST_FIRST))
                charged += rc.metadata.usage.cost
                if rc.metadata.context_strategy == "declined":
                    declines += 1
        ledger = bridge.ledger.summary()
        spent = sum(u["spent"] for u in ledger.values())
        return {
            "max_attempts": max_attempts,
            "availability": served / n,
            "mean_attempts": float(np.mean(attempts)),
            "retries": bridge.providers.retries,
            "exhausted": bridge.providers.exhausted,
            "ledger_spent": spent,
            "responses_cost": charged,
            "capped_budget": 6 * unit,
            "capped_remaining": ledger["capped"]["remaining"],
            "capped_declines": declines,
            "providers": bridge.stats()["providers"],
        }

    static = trace(max_attempts=1)
    fleet = trace(max_attempts=4)
    # -- acceptance invariants (PR gate) ------------------------------------
    assert fleet["availability"] >= 0.99, fleet["availability"]
    assert static["availability"] <= 1.0 - ERROR_RATE / 2, \
        static["availability"]
    for row in (static, fleet):
        # ledger conservation: every unit charged is a unit of response
        # usage — failed attempts and retries bill nothing extra — and the
        # finite-budget user ends the run un-overdrawn
        assert abs(row["ledger_spent"] - row["responses_cost"]) < 1e-9, \
            (row["ledger_spent"], row["responses_cost"])
        assert row["capped_remaining"] >= -1e-9, row["capped_remaining"]
        assert row["capped_declines"] > 0, "decline boundary never exercised"
    return {"n": n, "error_rate": ERROR_RATE, "static": static,
            "fleet": fleet}


def run_hedge(n: int = N_HEDGE) -> dict:
    wl = _workload()
    # a stall tail: 12% of primary attempts hang to the 10s timeout — the
    # p95-tail case hedging exists for (clean latencies stay sub-second)
    spec = FaultSpec(timeout_rate=0.12, timeout_s=10.0, latency_sigma=0.15)

    def trace(hedge: bool) -> dict:
        bridge = build_bridge(workload=wl, seed=0)
        bridge.providers.hedge_enabled = hedge
        bridge.providers.max_attempts = 4
        _inject_all(bridge, spec)
        lats = []
        cost = 0.0
        for i in range(n):
            r = bridge.request(_req(
                wl, i,
                constraints=Constraints(allow_cache=False,
                                        allow_prefetch=False),
                preference=Preference.LATENCY_FIRST))
            lats.append(r.metadata.usage.latency)
            cost += r.metadata.usage.cost
        snap = bridge.stats()["providers"]
        return {
            "hedge": hedge,
            "p50_s": float(np.percentile(lats, 50)),
            "p95_s": float(np.percentile(lats, 95)),
            "p99_s": float(np.percentile(lats, 99)),
            "total_cost": cost,
            "hedges": snap["hedges"],
        }

    base = trace(hedge=False)
    hedged = trace(hedge=True)
    # -- acceptance invariants (PR gate) ------------------------------------
    assert hedged["hedges"]["fired"] > 0, "hedging never engaged"
    assert hedged["p95_s"] < base["p95_s"], \
        (hedged["p95_s"], base["p95_s"])
    overhead = (hedged["hedges"]["wasted_cost"]
                / max(hedged["total_cost"], 1e-12))
    return {"n": n, "timeout_rate": spec.timeout_rate, "no_hedge": base,
            "hedged": hedged, "wasted_cost_fraction": overhead}


def run_outage(n: int = N_OUTAGE) -> dict:
    wl = _workload()
    bridge = build_bridge(workload=wl, seed=0)
    bridge.providers.max_attempts = 3
    target = bridge.pool.cheapest().name
    # hard-down window on the fleet clock (requests advance it ~0.5s each);
    # a short-cooldown breaker so open -> half_open -> closed all land
    # within the run: probes fail and re-open while the outage holds, then
    # succeed and close it after t=25
    bridge.providers.configure(
        target, FaultSpec(outages=((5.0, 25.0),)),
        breaker=CircuitBreaker(failure_threshold=3, cooldown=6.0))
    phases = {"before": [], "during": [], "after": []}
    trail = []
    for i in range(n):
        now = bridge.providers.now()
        phase = ("before" if now < 5.0 else
                 "during" if now < 25.0 else "after")
        r = bridge.request(_req(wl, i))
        phases[phase].append(r.metadata.model_used != "error")
        trail.append((round(now, 2), r.metadata.provider,
                      r.metadata.provider_events))
    snap = bridge.stats()["providers"]["providers"][target]
    availability = {k: (float(np.mean(v)) if v else None)
                    for k, v in phases.items()}
    states = [t[2] for t in snap["transitions"]]
    # -- acceptance invariants (PR gate) ------------------------------------
    assert availability["during"] is None or availability["during"] >= 0.99, \
        availability
    assert "open" in states, f"breaker never opened: {snap['transitions']}"
    assert snap["state"] == "closed", \
        f"breaker never recovered: {snap['state']}"
    return {"n": n, "target": target, "availability": availability,
            "transitions": snap["transitions"],
            "requests_per_phase": {k: len(v) for k, v in phases.items()},
            "trail_head": trail[:6]}


def run(smoke: bool = False) -> dict:
    return {
        "availability": run_availability(N_AVAIL_SMOKE if smoke else N_AVAIL),
        "hedge": run_hedge(N_HEDGE_SMOKE if smoke else N_HEDGE),
        "outage": run_outage(N_OUTAGE),
    }


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short request counts for the CI PR gate (same asserts)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the full result dict as a JSON artifact")
    args = ap.parse_args()
    res = run(smoke=args.smoke)

    a = res["availability"]
    print(f"availability @ {a['error_rate']:.0%} injected errors, "
          f"n={a['n']}: static={a['static']['availability']:.3f} "
          f"fleet={a['fleet']['availability']:.3f} "
          f"(mean attempts {a['fleet']['mean_attempts']:.2f}, "
          f"{a['fleet']['retries']} retries)")
    h = res["hedge"]
    print(f"hedge @ {h['timeout_rate']:.0%} stall rate, n={h['n']}: "
          f"p95 {h['no_hedge']['p95_s']:.2f}s -> {h['hedged']['p95_s']:.2f}s "
          f"(p99 {h['no_hedge']['p99_s']:.2f}s -> {h['hedged']['p99_s']:.2f}s, "
          f"{h['hedged']['hedges']['fired']} fired / "
          f"{h['hedged']['hedges']['won']} won, "
          f"wasted cost {h['wasted_cost_fraction']:.1%} of spend)")
    o = res["outage"]
    print(f"outage on {o['target']}: availability "
          f"{ {k: (f'{v:.3f}' if v is not None else '-') for k, v in o['availability'].items()} } "
          f"transitions={o['transitions']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(jsonable(res), f, indent=2)
        print(f"wrote {args.json}")
