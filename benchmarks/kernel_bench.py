"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle — correctness
margin + CPU call time.  (TPU wall-clock is out of scope on this host; the
roofline table covers the production performance story.)"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.kernels.cache_topk import ops as topk_ops
from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention import tuning as da_tuning
from repro.kernels.flash_attention import ops as fa_ops


def _time(fn, n=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / n * 1e6


def run() -> List[Row]:
    rows: List[Row] = []
    rng = np.random.default_rng(0)

    q = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    db = jnp.asarray(rng.normal(size=(2048, 64)), jnp.float32)
    s_ref, i_ref = topk_ops.similarity_topk(q, db, 8, use_pallas=False)
    s_pl, i_pl = topk_ops.similarity_topk(q, db, 8, use_pallas=True)
    us = _time(lambda: topk_ops.similarity_topk(q, db, 8, use_pallas=False))
    rows.append(("kernel.cache_topk.64x2048xd64k8", us,
                 f"maxerr={np.abs(s_ref - s_pl).max():.1e} idx_match={np.array_equal(i_ref, i_pl)}"))

    codes = jnp.asarray(rng.integers(0, 7, 2048), jnp.int32)
    sl = jnp.asarray(rng.integers(-1, 2048, size=(64, 512)), jnp.int32)
    tm = jnp.asarray(rng.integers(1, 2 ** 7, 64), jnp.int32)
    th = jnp.asarray(rng.uniform(-0.5, 0.3, 64), jnp.float32)
    s_ref, i_ref = topk_ops.shortlist_topk(q, db, codes, sl, tm, th, 8,
                                           use_pallas=False)
    s_pl, i_pl = topk_ops.shortlist_topk(q, db, codes, sl, tm, th, 8,
                                         use_pallas=True)
    us = _time(lambda: topk_ops.shortlist_topk(q, db, codes, sl, tm, th, 8,
                                               use_pallas=False))
    live = np.asarray(i_ref) >= 0
    rows.append(("kernel.shortlist_topk.64x512of2048xd64k8", us,
                 f"maxerr={np.abs(s_ref[live] - s_pl[live]).max():.1e} "
                 f"idx_match={np.array_equal(i_ref, i_pl)}"))

    qa = jax.random.normal(jax.random.PRNGKey(0), (2, 256, 8, 64))
    ka = jax.random.normal(jax.random.PRNGKey(1), (2, 256, 2, 64))
    va = jax.random.normal(jax.random.PRNGKey(2), (2, 256, 2, 64))
    o_ref = fa_ops.flash_attention(qa, ka, va, use_pallas=False)
    o_pl = fa_ops.flash_attention(qa, ka, va, use_pallas=True)
    us = _time(lambda: fa_ops.flash_attention(qa, ka, va, use_pallas=False))
    rows.append(("kernel.flash_attention.B2S256H8", us,
                 f"maxerr={float(jnp.abs(o_ref - o_pl).max()):.1e}"))

    qd = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 64))
    kd = jax.random.normal(jax.random.PRNGKey(4), (4, 2048, 2, 64))
    vd = jax.random.normal(jax.random.PRNGKey(5), (4, 2048, 2, 64))
    pos = jnp.asarray([100, 500, 1000, 2000], jnp.int32)
    o_ref = da_ops.decode_attention(qd, kd, vd, pos, use_pallas=False)
    o_pl = da_ops.decode_attention(qd, kd, vd, pos, use_pallas=True)
    us = _time(lambda: da_ops.decode_attention(qd, kd, vd, pos, use_pallas=False))
    tile, src = da_tuning.tile_choice(2048, qd.dtype)
    rows.append(("kernel.decode_attention.B4T2048", us,
                 f"maxerr={float(jnp.abs(o_ref - o_pl).max()):.1e} "
                 f"tile_t={tile}({src})"))

    # paged decode attention: scattered page tables, grid stopped at each
    # slot's LIVE page count (not masked-out full-table sweeps)
    B, MP, P, Hkv, Hq, hd = 4, 16, 128, 2, 8, 64
    n_pages = B * MP + 1
    kp = jax.random.normal(jax.random.PRNGKey(6), (n_pages, P, Hkv, hd))
    vp = jax.random.normal(jax.random.PRNGKey(7), (n_pages, P, Hkv, hd))
    qp = jax.random.normal(jax.random.PRNGKey(8), (B, Hq, hd))
    tblh = rng.permutation(np.arange(1, n_pages))[:B * MP] \
        .reshape(B, MP).astype(np.int32)
    ppos = np.asarray([100, 500, 1000, 2000], np.int32)
    for b in range(B):
        tblh[b, ppos[b] // P + 1:] = -1
    tbl = jnp.asarray(tblh)
    posd = jnp.asarray(ppos)
    o_ref = da_ops.paged_decode_attention(qp, kp, vp, tbl, posd,
                                          use_pallas=False)
    o_pl = da_ops.paged_decode_attention(qp, kp, vp, tbl, posd,
                                         use_pallas=True)
    us = _time(lambda: da_ops.paged_decode_attention(qp, kp, vp, tbl, posd,
                                                     use_pallas=False))
    tile, src = da_tuning.tile_choice(MP * P, qp.dtype, page_size=P)
    rows.append((f"kernel.paged_decode_attention.B{B}MP{MP}P{P}", us,
                 f"maxerr={float(jnp.abs(o_ref - o_pl).max()):.1e} "
                 f"tile_t={tile}({src}) live-stop grid"))

    # paged flash prefill: (B, S) query blocks over page-table KV — suffix
    # prefill and speculative verify both decode through this kernel
    S = 8
    qs = jax.random.normal(jax.random.PRNGKey(9), (B, S, Hq, hd))
    spos = jnp.asarray(np.minimum(ppos, MP * P - S), jnp.int32)
    o_ref = da_ops.paged_prefill_attention(qs, kp, vp, tbl, spos,
                                           use_pallas=False)
    o_pl = da_ops.paged_prefill_attention(qs, kp, vp, tbl, spos,
                                          use_pallas=True)
    us = _time(lambda: da_ops.paged_prefill_attention(qs, kp, vp, tbl, spos,
                                                      use_pallas=False))
    tile, src = da_tuning.tile_choice(MP * P, qs.dtype, page_size=P)
    rows.append((f"kernel.paged_prefill_attention.B{B}S{S}MP{MP}P{P}", us,
                 f"maxerr={float(jnp.abs(o_ref - o_pl).max()):.1e} "
                 f"tile_t={tile}({src})"))
    return rows
