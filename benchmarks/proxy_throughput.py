"""Batched proxy throughput: requests/sec of the stage pipeline at B ∈
{1, 8, 32}.

For each batch size the planted smart-cache workload is replayed twice over
same-seed bridges: sequentially (``bridge.request`` per prompt) and through
the batched engine (``bridge.request_batch``).  Derived columns report the
requests/sec of each mode plus the embedder-call and vector-search counts
per batch — the batched path must collapse B sequential embed+search pairs
into ONE embedder forward pass and ONE multi-query ``VectorStore.search``
(the Pallas ``cache_topk`` hot path).
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core import (CachedType, ProxyRequest, ServiceType, Workload,
                        WorkloadConfig, build_bridge, jsonable)

BATCH_SIZES = (1, 8, 32)
REPEATS = 3
# --smoke (CI): one small batch size pair, single repeat — fails fast on
# API-surface regressions without burning CI minutes
SMOKE_BATCH_SIZES = (1, 8)
SMOKE_REPEATS = 1


def _workload():
    return Workload(WorkloadConfig(n_conversations=8, turns_per_conversation=8,
                                   seed=3))


def _fresh_bridge(wl):
    bridge = build_bridge(workload=wl, seed=0)
    for q in wl.queries[::2]:
        bridge.cache.put(q.text + " background facts. " * 5,
                         [(CachedType.CHUNK, q.text)], meta={"topic": q.topic})
    bridge.cache.embedder.n_calls = 0
    bridge.cache.store.n_searches = 0
    return bridge


def _requests(wl, n):
    qs = (wl.queries * ((n // len(wl.queries)) + 1))[:n]
    return [ProxyRequest(prompt=q.text, conversation=q.conversation,
                         service_type=ServiceType.SMART_CACHE, query=q,
                         update_context=False) for q in qs]


def _time_mode(wl, reqs, batched: bool, repeats: int = REPEATS):
    """Returns (best_seconds, embed_calls, searches, hits) over repeats."""
    best = float("inf")
    for _ in range(repeats):
        bridge = _fresh_bridge(wl)
        t0 = time.perf_counter()
        if batched:
            out = bridge.request_batch(reqs)
        else:
            out = [bridge.request(r) for r in reqs]
        best = min(best, time.perf_counter() - t0)
        embeds = bridge.cache.embedder.n_calls
        searches = bridge.cache.store.n_searches
        hits = sum(r.metadata.cache_hit for r in out)
    return best, embeds, searches, hits


def run(batch_sizes=BATCH_SIZES, repeats=REPEATS):
    rows = []
    wl = _workload()
    base_rps = None
    for B in batch_sizes:
        reqs = _requests(wl, B)
        for mode, batched in (("seq", False), ("batch", True)):
            secs, embeds, searches, hits = _time_mode(wl, reqs, batched,
                                                      repeats)
            rps = B / secs
            if B == 1 and mode == "seq":
                base_rps = rps
            derived = (f"rps={rps:.0f}; embed_calls={embeds}; "
                       f"searches={searches}; hits={hits}/{B}")
            if mode == "batch":
                # acceptance invariants: one embed pass + one multi-query
                # search per batch; batched rps beats the B=1 loop
                assert embeds == 1 and searches == 1, (B, embeds, searches)
                if base_rps is not None:
                    derived += f"; speedup_vs_B1={rps / base_rps:.2f}x"
                    if B > 1:
                        assert rps > base_rps, (B, rps, base_rps)
            rows.append((f"proxy_throughput.{mode}.B{B}", secs * 1e6 / B,
                         derived))
    return rows


def stage_cdf_artifact(B: int = 32) -> dict:
    """One batched replay's full telemetry: ``proxy.stats()`` plus the raw
    per-stage wall-time CDF curves (the paper's Fig 6 material) — the
    nightly CI job writes this JSON as a build artifact, the first step of
    the ROADMAP's stats-persistence item."""
    wl = _workload()
    bridge = _fresh_bridge(wl)
    bridge.request_batch(_requests(wl, B))
    stats = bridge.stats()
    cdfs = {}
    for stage in stats["paths"].get("request_batch", {}).get("stages", {}):
        xs, ys = bridge.stage_cdf("request_batch", stage)
        cdfs[stage] = {"wall_s": [float(x) for x in xs],
                       "cum_frac": [float(y) for y in ys]}
    return {"batch_size": B, "stats": stats, "stage_cdf": cdfs}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small batch sizes, single repeat (CI regression run)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write stats + per-stage CDF curves as JSON")
    args = ap.parse_args()
    kw = (dict(batch_sizes=SMOKE_BATCH_SIZES, repeats=SMOKE_REPEATS)
          if args.smoke else {})
    rows = run(**kw)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        artifact = stage_cdf_artifact(B=max(SMOKE_BATCH_SIZES if args.smoke
                                            else BATCH_SIZES))
        artifact["rows"] = [{"name": n, "us_per_req": u, "derived": d}
                            for n, u, d in rows]
        with open(args.json, "w") as f:
            json.dump(jsonable(artifact), f, indent=2)
        print(f"wrote {args.json}")
