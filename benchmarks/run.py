"""Benchmark harness: one module per paper table/figure (+ roofline/kernels).

Prints ``name,us_per_call,derived`` CSV.  Select subsets with
``python -m benchmarks.run [fig1 fig45 fig6 fig7 latency kernels roofline]``.
"""
from __future__ import annotations

import sys
import traceback

MODULES = {
    "fig1": ("benchmarks.context_lastk", "Fig 1a/1b last-k context"),
    "fig45": ("benchmarks.model_selection", "Fig 4/5 model selection"),
    "fig6": ("benchmarks.smart_context", "Fig 6 smart context"),
    "fig7": ("benchmarks.smart_cache", "Fig 7 smart cache"),
    "latency": ("benchmarks.serving_latency", "§5.1 latency table"),
    "throughput": ("benchmarks.proxy_throughput", "batched pipeline rps"),
    "kernels": ("benchmarks.kernel_bench", "kernel microbench"),
    "roofline": ("benchmarks.roofline_table", "§Roofline table"),
}


def main() -> None:
    want = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    failed = []
    for key in want:
        mod_name, _desc = MODULES[key]
        try:
            mod = __import__(mod_name, fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.1f},{str(derived).replace(',', ';')}")
        except Exception:
            failed.append(key)
            traceback.print_exc()
            print(f"{key}.FAILED,0.0,exception")
    if failed:
        raise SystemExit(f"benchmark failures: {failed}")


if __name__ == "__main__":
    main()
