"""Paper Fig 1a/1b: last-k context cost growth + quality vs full context.

Claims validated:
* k=N input tokens grow quadratically; with the paper's I/O ratio the full-
  context conversation uses ~55x the input tokens of k=0 and k=1 is ~3x;
* quality gap between k=0 and full context concentrates in the tail ~20%.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, replay, timed
from repro.core import ServiceType, Workload, WorkloadConfig, build_bridge


def run() -> List[Row]:
    # one 50-query conversation, paper's I/O ratio (output ~1.2x input)
    wl = Workload(WorkloadConfig(n_conversations=1, turns_per_conversation=50,
                                 seed=11, output_multiplier=1.2))
    rows: List[Row] = []
    toks = {}
    quals = {}
    for k in (0, 1, 5, 10, 50):
        bridge = build_bridge(workload=wl, seed=0)
        recs, us = timed(replay, bridge, wl, ServiceType.FIXED,
                         {"model": "gemma3-27b", "context_k": k})
        toks[k] = sum(r["in_tokens"] for r in recs)
        quals[k] = [r["quality"] for r in recs]
        rows.append((f"fig1a.last_k{k}.input_tokens", us / len(recs),
                     str(toks[k])))
    ratio_full = toks[50] / max(toks[0], 1)
    ratio_k1 = toks[1] / max(toks[0], 1)
    rows.append(("fig1a.ratio_k50_vs_k0", 0.0, f"{ratio_full:.1f}x (paper ~55x)"))
    rows.append(("fig1a.ratio_k1_vs_k0", 0.0, f"{ratio_k1:.1f}x (paper ~3x)"))

    # Fig 1b: quality of k=0 vs k=50 reference — gap lives in the tail
    q0, qfull = np.array(quals[0]), np.array(quals[50])
    gap_median = float(np.median(qfull) - np.median(q0))
    gap_p10 = float(np.percentile(qfull, 10) - np.percentile(q0, 10))
    rows.append(("fig1b.gap_median", 0.0, f"{gap_median:.2f}pts"))
    rows.append(("fig1b.gap_p10_tail", 0.0,
                 f"{gap_p10:.2f}pts (tail >> median: {gap_p10 > 2 * max(gap_median, 0.05)})"))
    return rows
