"""Paper Fig 4a/4b (quality) + Fig 5a/5b (cost/time): verification-based
model selection vs M1-only / M2-only / random routing.

Claims validated:
* old-generation models: verification routes >60% of prompts to M2, beats
  M1-only quality, costs ~40% less than M2-only (Fig 5a), sits between
  M1-only and M2-only in time (~5x M1, Fig 5b);
* new-generation models: only ~25% routed to M2 (cheap models got better),
  quality gap nearly closed (Fig 4b);
* random routing at the matched probability is comparable, but the right p
  isn't knowable a priori (p=0.1 is worse).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, timed
from repro.core import (ProxyRequest, ServiceType, Workload, WorkloadConfig,
                        build_bridge)

M1, M2 = "qwen2-1.5b", "grok-1-314b"


def _replay_selector(bridge, wl, threshold=8.0):
    recs = []
    for q in wl.queries:
        r = bridge.request(ProxyRequest(
            prompt=q.text, conversation=q.conversation, query=q,
            service_type=ServiceType.MODEL_SELECTOR,
            params={"m1": M1, "m2": M2, "verifier": "xlstm-350m",
                    "threshold": threshold, "context_k": 5}))
        recs.append(r)
    return recs


def _replay_fixed(bridge, wl, model, p_big=None, seed=0):
    rng = np.random.default_rng(seed)
    recs = []
    for q in wl.queries:
        m = model
        if p_big is not None:
            m = M2 if rng.random() < p_big else M1
        r = bridge.request(ProxyRequest(
            prompt=q.text, conversation=q.conversation, query=q,
            service_type=ServiceType.FIXED,
            params={"model": m, "context_k": 5}))
        recs.append(r)
    return recs


def _stats(recs):
    qual = [r.true_quality for r in recs if r.true_quality is not None]
    cost = sum(r.metadata.usage.cost for r in recs)
    lat = sum(r.metadata.usage.latency for r in recs)
    return np.mean(qual), np.percentile(qual, 10), cost, lat


def run() -> List[Row]:
    rows: List[Row] = []
    wl = Workload(WorkloadConfig(n_conversations=10, turns_per_conversation=25,
                                 seed=5))
    for gen in ("old", "new"):
        bridge = build_bridge(workload=wl, seed=0, generation=gen)
        if gen == "old":
            # GPT-3.5-era cheap model: degrade M1 and the verifier
            bridge.pool.get(M1).generation_bonus = -0.30
            bridge.pool.get("xlstm-350m").generation_bonus = -0.30

        sel, us = timed(_replay_selector, bridge, wl)
        routed_m2 = np.mean([M2 in r.metadata.models_consulted for r in sel])
        sq, sq10, sc, sl = _stats(sel)

        b1 = build_bridge(workload=wl, seed=0, generation=gen)
        if gen == "old":
            b1.pool.get(M1).generation_bonus = -0.30
        m1 = _replay_fixed(b1, wl, M1)
        m1q, m1q10, m1c, m1l = _stats(m1)
        b2 = build_bridge(workload=wl, seed=0, generation=gen)
        m2 = _replay_fixed(b2, wl, M2)
        m2q, m2q10, m2c, m2l = _stats(m2)

        p_match = float(routed_m2)
        br = build_bridge(workload=wl, seed=0, generation=gen)
        if gen == "old":
            br.pool.get(M1).generation_bonus = -0.30
        rnd = _replay_fixed(br, wl, None, p_big=p_match)
        rq, rq10, rc, rl = _stats(rnd)
        br2 = build_bridge(workload=wl, seed=0, generation=gen)
        if gen == "old":
            br2.pool.get(M1).generation_bonus = -0.30
        rnd10 = _replay_fixed(br2, wl, None, p_big=0.1)
        r10q, r10q10, r10c, _ = _stats(rnd10)

        tag = f"fig4{'a' if gen == 'old' else 'b'}.{gen}"
        rows += [
            (f"{tag}.verification.quality", us / len(wl.queries),
             f"mean={sq:.2f} p10={sq10:.2f} routed_m2={routed_m2:.0%}"),
            (f"{tag}.m1_only.quality", 0.0, f"mean={m1q:.2f} p10={m1q10:.2f}"),
            (f"{tag}.m2_only.quality", 0.0, f"mean={m2q:.2f} p10={m2q10:.2f}"),
            (f"{tag}.random_p{p_match:.2f}.quality", 0.0, f"mean={rq:.2f}"),
            (f"{tag}.random_p0.1.quality", 0.0, f"mean={r10q:.2f} p10={r10q10:.2f}"),
        ]
        if gen == "old":
            rows += [
                ("fig5a.cost_vs_m2_only", 0.0,
                 f"{sc / m2c:.2f} (paper ~0.60: 40% cheaper)"),
                ("fig5b.time_vs_m1_only", 0.0,
                 f"{sl / m1l:.1f}x (paper ~5x)"),
                ("fig5b.time_vs_m2_only", 0.0,
                 f"{sl / m2l:.2f} (<1 means faster than M2-only)"),
            ]
        else:
            rows.append(("fig4b.routed_fraction_new", 0.0,
                         f"{routed_m2:.0%} (paper ~25%)"))
    return rows
