"""§Roofline summary: read the dry-run JSON records and emit the per-
(arch x shape x mesh) three-term table rows."""
from __future__ import annotations

import glob
import json
import os
from typing import List

from benchmarks.common import Row

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records():
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run() -> List[Row]:
    rows: List[Row] = []
    recs = load_records()
    if not recs:
        return [("roofline.missing", 0.0,
                 f"no dry-run records in {DRYRUN_DIR}; run "
                 "python -m repro.launch.dryrun --all --both-meshes")]
    n_ok = n_skip = n_err = 0
    for r in recs:
        tag = f"{r.get('arch')}.{r.get('shape')}.{r.get('mesh')}"
        if r.get("skipped"):
            n_skip += 1
            rows.append((f"roofline.skip.{tag}", 0.0, "documented skip"))
            continue
        if "error" in r:
            n_err += 1
            rows.append((f"roofline.ERROR.{tag}", 0.0, r["error"].splitlines()[-1][:80]))
            continue
        n_ok += 1
        rows.append((
            f"roofline.{tag}", r.get("compile_seconds", 0) * 1e6,
            f"compute={r['t_compute']*1e3:.2f}ms memory={r['t_memory']*1e3:.2f}ms "
            f"collective={r['t_collective']*1e3:.2f}ms dominant={r['dominant']} "
            f"useful={r['useful_ratio']:.2f} mfu={r['mfu']:.3f}"))
    rows.append(("roofline.summary", 0.0,
                 f"ok={n_ok} skipped={n_skip} errors={n_err}"))
    return rows
