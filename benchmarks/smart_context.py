"""Paper Fig 6a/6b/6c: SmartContext vs last-k.

Claims validated:
* smart_context with k=1 / k=5 is ~30% / ~50% cheaper than the matching
  last-k strategies;
* quality falls between k=0 and k=1 (most of the benefit of context is
  already captured); the k=0 tail is the worst;
* the extra decider call costs <20% of total request time for ~80% of
  messages (k=1).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import Row, replay, timed
from repro.core import ServiceType, Workload, WorkloadConfig, build_bridge

MODEL = "gemma3-27b"


def run() -> List[Row]:
    wl = Workload(WorkloadConfig(n_conversations=10, turns_per_conversation=25,
                                 seed=6))
    rows: List[Row] = []
    res = {}
    for name, st, params in [
        ("last_k0", ServiceType.FIXED, {"model": MODEL, "context_k": 0}),
        ("last_k1", ServiceType.FIXED, {"model": MODEL, "context_k": 1}),
        ("last_k5", ServiceType.FIXED, {"model": MODEL, "context_k": 5}),
        ("smart_k1", ServiceType.SMART_CONTEXT, {"model": MODEL, "context_k": 1}),
        ("smart_k5", ServiceType.SMART_CONTEXT, {"model": MODEL, "context_k": 5}),
    ]:
        bridge = build_bridge(workload=wl, seed=0)
        big = bridge.pool.get(MODEL)
        recs, us = timed(replay, bridge, wl, st, params)
        # Fig 6a measures the *input-side* cost (the strategy-dependent part;
        # paper Fig 1a/6a count input tokens) — output cost is identical
        # across strategies and would dilute the comparison.
        cost = sum(r["cost"] - r["out_tokens"] / 1e3 * big.price_out
                   for r in recs)
        qual = [r["quality"] for r in recs]
        res[name] = {"cost": cost, "qual": qual, "recs": recs, "us": us}
        rows.append((f"fig6a.{name}", us / len(recs),
                     f"in_cost={cost:.2f} meanQ={np.mean(qual):.2f} "
                     f"p10={np.percentile(qual, 10):.2f}"))

    s1 = 1 - res["smart_k1"]["cost"] / res["last_k1"]["cost"]
    s5 = 1 - res["smart_k5"]["cost"] / res["last_k5"]["cost"]
    rows.append(("fig6a.smart_k1_savings", 0.0, f"{s1:.0%} (paper ~30%)"))
    rows.append(("fig6a.smart_k5_savings", 0.0, f"{s5:.0%} (paper ~50%)"))

    q0 = np.mean(res["last_k0"]["qual"])
    q1 = np.mean(res["last_k1"]["qual"])
    qs = np.mean(res["smart_k5"]["qual"])
    rows.append(("fig6b.smart_between_k0_and_k1", 0.0,
                 f"k0={q0:.2f} <= smart={qs:.2f} ~ k1={q1:.2f}: "
                 f"{bool(q0 - 0.05 <= qs)}"))

    # Fig 6c: decision time as a fraction of request time (smart k=1)
    fr = [r["decision_latency"] / max(r["latency"], 1e-9)
          for r in res["smart_k1"]["recs"]]
    frac80 = float(np.percentile(fr, 80))
    rows.append(("fig6c.decision_time_frac_p80", 0.0,
                 f"{frac80:.0%} of request time (paper <20%)"))
    rows.append(("fig6c.decision_time_frac_max", 0.0,
                 f"{float(np.max(fr)):.0%} (paper <50%)"))
    return rows
