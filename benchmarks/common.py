"""Shared benchmark helpers: workload replay with strategy overrides and
CSV row plumbing (``name,us_per_call,derived``)."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np

Row = Tuple[str, float, str]


def timed(fn: Callable, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def cdf_points(values, qs=(5, 10, 25, 50, 75, 90, 95)) -> Dict[int, float]:
    return {q: float(np.percentile(values, q)) for q in qs}


def replay(bridge, workload, service_type, params=None, queries=None):
    """Replay queries through a bridge; returns per-query records."""
    from repro.core import ProxyRequest
    recs = []
    queries = queries if queries is not None else workload.queries
    for q in queries:
        r = bridge.request(ProxyRequest(prompt=q.text, conversation=q.conversation,
                                        service_type=service_type, query=q,
                                        params=params or {}))
        u = r.metadata.usage
        recs.append({
            "qid": q.qid, "quality": r.true_quality,
            "cost": u.cost, "latency": u.latency,
            "in_tokens": u.input_tokens, "out_tokens": u.output_tokens,
            "extra_in": u.extra_llm_input_tokens,
            "model": r.metadata.model_used,
            "models": r.metadata.models_consulted,
            "cache_hit": r.metadata.cache_hit,
            "context_k": r.metadata.context_k,
            "decision_latency": r.metadata.context_decision_latency,
        })
    return recs


def agg(recs, field):
    vals = [r[field] for r in recs if r[field] is not None]
    return float(np.sum(vals)) if field in ("cost", "in_tokens") else float(np.mean(vals))
