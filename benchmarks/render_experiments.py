"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run JSON
records.  Usage: PYTHONPATH=src python -m benchmarks.render_experiments
(prints markdown to stdout)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCHS = ["llava-next-mistral-7b", "gemma-2b", "llama4-maverick-400b-a17b",
         "gemma3-27b", "grok-1-314b", "qwen2-1.5b", "zamba2-7b",
         "granite-3-2b", "xlstm-350m", "whisper-base"]


def load():
    recs = {}
    for p in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
        with open(p) as f:
            r = json.load(f)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs, mesh):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | compile | args GiB/dev | temp GiB/dev | "
          "flops/dev | bytes/dev | coll bytes/dev | top collectives |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if r.get("skipped"):
                print(f"| {arch} | {shape} | skip | | | | | | "
                      f"{r['reason'][:40]}… |")
                continue
            if "error" in r:
                print(f"| {arch} | {shape} | ERROR | | | | | | |")
                continue
            chips = r["chips"]
            coll = sorted(r["collective_by_op"].items(), key=lambda kv: -kv[1])
            tops = "; ".join(f"{k}={v/2**30:.1f}GiB" for k, v in coll[:2])
            print(f"| {arch} | {shape} | {r['compile_seconds']:.0f}s "
                  f"| {fmt_bytes(r['mem_args'])} | {fmt_bytes(r['mem_temp'])} "
                  f"| {r['flops_global']/chips:.2e} "
                  f"| {r['bytes_global']/chips:.2e} "
                  f"| {r['collective_bytes_global']/chips:.2e} | {tops} |")


def roofline_table(recs, mesh="pod16x16"):
    print(f"\n### Roofline terms (single pod, {mesh}, per step, seconds)\n")
    print("| arch | shape | compute | memory | collective | dominant | "
          "MODEL_FLOPS | useful | MFU@roofline |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCHS:
        for shape in SHAPES:
            r = recs.get((arch, shape, mesh))
            if r is None or r.get("skipped") or "error" in r:
                continue
            print(f"| {arch} | {shape} | {r['t_compute']*1e3:.1f}ms "
                  f"| {r['t_memory']*1e3:.1f}ms | {r['t_collective']*1e3:.1f}ms "
                  f"| **{r['dominant']}** | {r['model_flops']:.2e} "
                  f"| {r['useful_ratio']:.2f} | {r['mfu']*100:.2f}% |")


def main():
    recs = load()
    n = len(recs)
    ok = sum(1 for r in recs.values() if not r.get("skipped") and "error" not in r)
    sk = sum(1 for r in recs.values() if r.get("skipped"))
    er = sum(1 for r in recs.values() if "error" in r)
    print(f"records: {n} (ok={ok} skipped={sk} errors={er})")
    print("\n## §Dry-run")
    for mesh in ("pod16x16", "pod2x16x16"):
        dryrun_table(recs, mesh)
    print("\n## §Roofline")
    roofline_table(recs)


if __name__ == "__main__":
    main()
