"""Streaming TTFT vs full-response latency (ISSUE 8 acceptance).

Two layers, reported separately:

* **scheduler leg** — the step-wise generator API (``Scheduler.run_stream``)
  on the real (reduced, CPU) engine across output lengths, paged KV and
  speculative decoding on: per-request time-to-first-token (the prefill
  argmax surfacing as the first stream event) vs the full-response wall
  time.  Spec decoding uses the Oracle draft at a controlled acceptance so
  the burst cadence is reproducible.
* **proxy leg** — ``LLMBridge.request_stream`` end-to-end over an
  engine-backed pool model: ``Metadata.ttft`` (disclosed on the final
  chunk's response) vs the measured full-stream wall time, plus the
  proxy-wide ``stats()["serving"]["ttft_cdf"]``.

The acceptance gate: at >=128-token outputs, TTFT < 25% of the
full-response latency — streaming delivers the first token while the
buffered path would still be decoding.

CLI: ``--smoke`` runs the 128-token points with hard assertions (PR gate);
``--json PATH`` writes the sweep as a nightly artifact; ``--full`` adds
the shorter output lengths.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

try:
    from benchmarks.common import Row
except ModuleNotFoundError:      # invoked as a script: repo root not on path
    import pathlib
    import sys
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent))
    from benchmarks.common import Row

MAX_LEN = 192
N_SLOTS = 4


def _engine():
    import jax
    from repro import configs
    from repro.models import init_model
    from repro.serving.engine import Engine
    cfg = configs.get_reduced("qwen2-1.5b")
    return Engine(cfg, init_model(cfg, jax.random.PRNGKey(0)),
                  max_len=MAX_LEN)


def _prompts(seed=0, n=N_SLOTS, length=16):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(3, 90, length).tolist(), jnp.int32)
            for _ in range(n)]


def _run_stream(engine, out_len, draft=None, spec_k=4, seed=0):
    """One streamed batch; returns (per-rid ttft, per-rid total, baseline
    continuations for the oracle draft)."""
    from repro.serving.scheduler import Request, Scheduler
    sch = Scheduler(engine, n_slots=N_SLOTS, paged=True, page_size=16,
                    draft=draft, spec_k=spec_k)
    for i, p in enumerate(_prompts(seed=seed)):
        sch.submit(Request(rid=i, user=f"u{i}", prompt=p, max_new=out_len))
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    gen: Dict[int, list] = {}
    t0 = time.perf_counter()
    for req, new_toks, done in sch.run_stream():
        now = time.perf_counter() - t0
        first.setdefault(req.rid, now)
        last[req.rid] = now
        gen.setdefault(req.rid, []).extend(new_toks)
    return first, last, gen


def scheduler_leg(out_lens) -> (List[Row], Dict):
    from repro.serving.engine import OracleDraftEngine
    engine = _engine()
    rows: List[Row] = []
    artifact: Dict = {"scheduler": []}
    # warm passes: jit-compile prefill + decode AND the spec draft/verify
    # shapes before anything is timed
    _, _, warm_gen = _run_stream(engine, 8)
    warm_draft = OracleDraftEngine(engine, n_slots=N_SLOTS, max_len=MAX_LEN,
                                   continuations=warm_gen, accept_p=0.8,
                                   seed=1)
    _run_stream(engine, 8, draft=warm_draft)
    for out_len in out_lens:
        # paged baseline (also records continuations for the oracle draft)
        first, last, gen = _run_stream(engine, out_len)
        ttft, total = np.mean(list(first.values())), np.mean(list(last.values()))
        rows.append((f"streaming.scheduler.paged.out{out_len}", ttft * 1e6,
                     f"ttft={ttft*1e3:.1f}ms total={total*1e3:.1f}ms "
                     f"ratio={ttft/total:.3f}"))
        artifact["scheduler"].append(
            {"backend": "paged", "out_len": out_len,
             "ttft_s": ttft, "total_s": total})

        # speculative: oracle draft at 0.8 acceptance over the same prompts
        draft = OracleDraftEngine(engine, n_slots=N_SLOTS, max_len=MAX_LEN,
                                  continuations=gen, accept_p=0.8, seed=1)
        sfirst, slast, sgen = _run_stream(engine, out_len, draft=draft)
        assert sgen == gen, "spec-decode stream diverged from plain greedy"
        sttft = np.mean(list(sfirst.values()))
        stotal = np.mean(list(slast.values()))
        rows.append((f"streaming.scheduler.spec.out{out_len}", sttft * 1e6,
                     f"ttft={sttft*1e3:.1f}ms total={stotal*1e3:.1f}ms "
                     f"ratio={sttft/stotal:.3f}"))
        artifact["scheduler"].append(
            {"backend": "spec", "out_len": out_len,
             "ttft_s": sttft, "total_s": stotal})
    return rows, artifact


def proxy_leg(out_lens) -> (List[Row], Dict):
    """End-to-end ``request_stream`` with ``Metadata.ttft`` disclosed."""
    from repro import configs
    from repro.core import (Constraints, ModelPool, PoolModel, Preference,
                            ProxyRequest, build_bridge,
                            pool_model_from_config)
    from repro.data.tokenizer import ByteTokenizer
    engine = _engine()
    base = pool_model_from_config(configs.get("qwen2-1.5b"))
    pool = ModelPool()
    pool.add(PoolModel(name=base.name, active_params=base.active_params,
                       capability=base.capability, engine=engine,
                       tokenizer=ByteTokenizer()))
    bridge = build_bridge(pool=pool)
    bridge.adapter.max_engine_tokens = MAX_LEN    # let long outputs through
    rows: List[Row] = []
    artifact: Dict = {"proxy": []}

    def req(user, out_len):
        return ProxyRequest(prompt="streaming latency probe", user=user,
                            constraints=Constraints(allow_cache=False),
                            preference=Preference.COST_FIRST,
                            params={"max_tokens": out_len})

    list(bridge.request_stream(req("warm", 8)))   # jit warm-up
    for out_len in out_lens:
        t0 = time.perf_counter()
        chunks = list(bridge.request_stream(req(f"u{out_len}", out_len)))
        total = time.perf_counter() - t0
        md = chunks[-1].response.metadata
        assert md.ttft is not None, "Metadata.ttft not disclosed"
        assert md.stream and not md.stream_cancelled
        rows.append((f"streaming.proxy.out{out_len}", md.ttft * 1e6,
                     f"ttft={md.ttft*1e3:.1f}ms total={total*1e3:.1f}ms "
                     f"ratio={md.ttft/total:.3f} "
                     f"inter_p50={md.inter_token_p50*1e3:.2f}ms"))
        artifact["proxy"].append({"out_len": out_len, "ttft_s": md.ttft,
                                  "total_s": total,
                                  "inter_token_p50_s": md.inter_token_p50})
    artifact["serving_stats"] = bridge.stats()["serving"]
    return rows, artifact


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="128-token points with hard assertions (PR gate)")
    ap.add_argument("--json", metavar="PATH",
                    help="write the sweep as a JSON artifact")
    ap.add_argument("--full", action="store_true",
                    help="add the shorter output lengths")
    args = ap.parse_args()

    out_lens = (16, 64, 128) if args.full else (128,)
    sched_rows, sched_art = scheduler_leg(out_lens)
    proxy_rows, proxy_art = proxy_leg(out_lens)
    rows = sched_rows + proxy_rows
    for name, us, derived in rows:
        print(f"{name:44s} {us:12.1f}us  {derived}")

    # acceptance: at >=128-token outputs TTFT < 25% of full-response latency
    checked = 0
    for rec in sched_art["scheduler"] + proxy_art["proxy"]:
        if rec["out_len"] >= 128:
            ratio = rec["ttft_s"] / rec["total_s"]
            assert ratio < 0.25, \
                f"TTFT ratio {ratio:.3f} >= 0.25 at out_len={rec['out_len']}"
            checked += 1
    assert checked >= 3, "acceptance points missing"
    print(f"acceptance: TTFT < 25% of full-response latency "
          f"({checked} points at >=128 tokens)")

    if args.json:
        from repro.core import jsonable
        with open(args.json, "w") as f:
            json.dump(jsonable({"rows": [list(r) for r in rows],
                                **sched_art, **proxy_art}), f, indent=2)
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
